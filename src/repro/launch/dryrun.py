import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, with ShapeDtypeStruct stand-ins
(zero allocation), and derive the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape decode_32k --mesh single --policy full
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

The two lines above this docstring MUST stay the first statements in the
file: jax locks the device count at first init, and the 512 placeholder
host devices exist only for this entry point (tests/benches see 1 device).
"""

import argparse
import json
import time
from dataclasses import asdict
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, CacheConfig, get_arch, get_shape
from repro.core.policies import get_policy
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.models.multimodal import input_specs
from repro.models.transformer import (
    decode_step,
    forward_prefill,
    init_decode_caches,
    init_model,
)
from repro.sharding import rules
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def default_policy(shape_name: str) -> str:
    """Baseline policy per shape (see EXPERIMENTS.md §Dry-run):
    decode_32k baselines with the full cache (cache of seq_len, as the
    assignment specifies); long_500k REQUIRES the paper's budget-capped
    cache (that is the sub-quadratic mechanism; DESIGN.md §4)."""
    return {"train_4k": "full", "prefill_32k": "full",
            "decode_32k": "full", "long_500k": "paged_eviction"}[shape_name]


def make_cache_cfg(policy: str, budget: int, page: int,
                   cache_dtype: str = "bfloat16") -> CacheConfig:
    return CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       slab_multiple=16, dtype=cache_dtype)


def build_lowerable(arch: str, shape_name: str, mesh, policy_name: str,
                    budget: int, page: int, zero1: bool,
                    cache_dtype: str = "bfloat16", seq_parallel: bool = False):
    """Returns (jitted_fn, example_args) ready for .lower(*args)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    B = shape.global_batch
    params_shape = jax.eval_shape(partial(init_model, cfg=cfg),
                                  jax.random.PRNGKey(0))
    p_sh = rules.param_shardings(mesh, cfg, params_shape)
    ac = rules.activation_constraint(mesh, B, seq_parallel=seq_parallel)
    specs = input_specs(cfg, shape, for_decode=(shape.kind == "decode"))

    if shape.kind == "train":
        opt_shape = jax.eval_shape(init_adamw, params_shape)
        o_sh = rules.opt_shardings(mesh, cfg, opt_shape, p_sh, zero1=zero1)
        batch = {
            "tokens": specs["tokens"],
            "targets": jax.ShapeDtypeStruct(specs["tokens"].shape, jnp.int32),
            "mask": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.float32),
        }
        b_sh = rules.data_shardings(mesh, batch)
        opt_cfg = AdamWConfig()
        step = make_train_step(cfg, opt_cfg, ac=ac,
                               moment_shardings=o_sh.mu if zero1 else None)
        if cfg.cross_attention:
            fn = lambda p, o, b, c: step(p, o, b, cond=c)
            cond_sh = rules.data_shardings(mesh, specs["cond"])
            jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh, cond_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
            return jfn, (params_shape, opt_shape, batch, specs["cond"])
        fn = lambda p, o, b: step(p, o, b)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        return jfn, (params_shape, opt_shape, batch)

    policy = get_policy(policy_name)
    ccfg = make_cache_cfg(policy_name, budget, page, cache_dtype)

    if shape.kind == "prefill":
        def fn(p, tokens, cond=None):
            return forward_prefill(p, cfg, tokens, policy, ccfg, cond=cond,
                                   ac=ac, total_seq_hint=shape.seq_len)
        tok_sh = rules.data_shardings(mesh, specs["tokens"])
        if cfg.cross_attention:
            cond_sh = rules.data_shardings(mesh, specs["cond"])
            jfn = jax.jit(fn, in_shardings=(p_sh, tok_sh, cond_sh))
            return jfn, (params_shape, specs["tokens"], specs["cond"])
        jfn = jax.jit(fn, in_shardings=(p_sh, tok_sh))
        return jfn, (params_shape, specs["tokens"])

    # decode: one token against a cache covering shape.seq_len
    cache_shape = jax.eval_shape(
        partial(init_decode_caches, cfg, B, shape.seq_len, policy, ccfg))
    c_sh = rules.cache_shardings(mesh, cfg, cache_shape, B)

    def fn(p, tokens, cache):
        return decode_step(p, cfg, tokens, cache, policy, ccfg, ac=ac)

    tok_sh = rules.data_shardings(mesh, specs["tokens"])
    jfn = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh),
                  out_shardings=(None, c_sh), donate_argnums=(2,))
    return jfn, (params_shape, specs["tokens"], cache_shape)


def run_one(arch: str, shape_name: str, mesh_name: str, policy_name: str,
            budget: int, page: int, zero1: bool, out_dir: str,
            verbose: bool = True, cache_dtype: str = "bfloat16",
            seq_parallel: bool = False, layout: str = "2d") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"),
                                layout=layout)
    chips = mesh.size
    shape = get_shape(shape_name)
    cfg = get_arch(arch)
    t0 = time.perf_counter()
    with mesh:
        jfn, args = build_lowerable(arch, shape_name, mesh, policy_name,
                                    budget, page, zero1, cache_dtype,
                                    seq_parallel)
        # trip-count-aware flop/byte counts from the jaxpr (XLA's
        # cost_analysis counts scan bodies once — see analysis.jaxpr_cost)
        jpr = jax.make_jaxpr(jfn)(*args)
        jflops, jbytes = analysis.jaxpr_cost(jpr)
        lowered = jfn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_total = time.perf_counter() - t0
    r = analysis.analyze(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        policy=policy_name, kind=shape.kind, chips=chips,
        model_flops=analysis.model_flops_estimate(cfg, shape),
        compile_seconds=t_total,
        default_group=16,
        jaxpr_flops=jflops, jaxpr_bytes=jbytes,
        notes=f"budget={budget} page={page} zero1={zero1} "
              f"cache_dtype={cache_dtype} seq_parallel={seq_parallel} "
              f"layout={layout} lower_s={t_lower:.1f}")
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{mesh_name}_{policy_name}" + \
          ("_zero1" if zero1 else "") + \
          (f"_{cache_dtype}" if cache_dtype != "bfloat16" else "") + \
          ("_sp" if seq_parallel else "") + \
          (f"_{layout}" if layout != "2d" else "")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(asdict(r), f, indent=1)
    if verbose:
        ma = r.memory_analysis
        print(f"[dryrun] {tag}: OK compile={t_total:.1f}s "
              f"compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s "
              f"collective={r.collective_s:.3e}s dominant={r.dominant} "
              f"useful={r.useful_flops_ratio:.2f} mem={ma}")
    return asdict(r)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--policy", default=None,
                    help="eviction policy (default: per-shape baseline)")
    ap.add_argument("--budget", type=int, default=4096)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer moments over data (ZeRO-1)")
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=("bfloat16", "float32", "int8"),
                    help="KV cache dtype (int8 = quantized cache)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-style sequence-parallel layer inputs")
    ap.add_argument("--layout", default="2d", choices=("2d", "ep"),
                    help="mesh layout: 2d=(data,model); ep=(data,expert,tp) "
                         "expert-parallel MoE")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) for --mesh")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    args = ap.parse_args()

    from repro.configs import ASSIGNED_ARCHS
    combos = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    failures = []
    for a, s in combos:
        pol = args.policy or default_policy(s)
        try:
            run_one(a, s, args.mesh, pol, args.budget, args.page,
                    args.zero1, args.out, cache_dtype=args.cache_dtype,
                    seq_parallel=args.seq_parallel, layout=args.layout)
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"[dryrun] {a} x {s} x {args.mesh} x {pol}: FAIL {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
