"""Serving driver: continuous batching with a selectable eviction policy.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --policy paged_eviction --budget 64 --page 8 --requests 8 \
        --trace /tmp/trace.jsonl --snapshot /tmp/metrics.json

Prints the obs metrics dashboard (latency histograms with p50/p90/p99,
pool counters) after the run; ``--trace`` additionally writes one JSONL
event per engine step (schema: repro.obs.trace, validate with
``python -m repro.obs.trace FILE``)."""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import CacheConfig, get_arch
from repro.models.transformer import init_model
from repro.obs import ObsConfig
from repro.serving import Engine, SamplingParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="paged_eviction")
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--page", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=64,
                    help="prefill chunk size (tokens/step/request)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="max tokens per unified step (default "
                         "max_batch + chunk)")
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable CoW prefix sharing across requests")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every request this many common leading "
                         "prompt tokens (exercises prefix sharing)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="write a per-step JSONL trace here")
    ap.add_argument("--timeline", default=None, metavar="FILE",
                    help="write a Chrome-trace/Perfetto JSON timeline of "
                         "per-request spans here (load in chrome://tracing "
                         "or ui.perfetto.dev)")
    ap.add_argument("--lineage", action="store_true",
                    help="keep a host-side page-lineage ledger (emits v2 "
                         "'event' records into --trace and prints a "
                         "reconciliation + per-request loss summary)")
    ap.add_argument("--regret-every", type=int, default=0, metavar="N",
                    help="probe eviction regret every N decode steps per "
                         "request against an uncompressed shadow cache "
                         "(0 = off; emits v2 'probe' records into --trace)")
    ap.add_argument("--snapshot", default=None, metavar="FILE",
                    help="write the final metrics snapshot (JSON) here")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel degree: serve the unified step "
                         "shard_map'd over an N-device (1, N) mesh — KV-head-"
                         "sharded pool/kernels, replicated scheduler "
                         "(DESIGN.md §11). On CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--no-metrics", action="store_true",
                    help="disable all engine instrumentation (the bare "
                         "baseline the BENCH_obs overhead gate compares to)")
    ap.add_argument("--profile-annotations", action="store_true",
                    help="wrap plan/step in jax.profiler.TraceAnnotation")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(tp=args.tp)
    if cfg.num_codebooks > 1:
        raise SystemExit("serve driver targets text archs; see examples/ for "
                         "audio decode")
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    ccfg = CacheConfig(page_size=args.page, cache_budget=args.budget,
                       policy=args.policy,
                       dtype="float32" if args.reduced else "bfloat16")
    obs = ObsConfig(metrics=not args.no_metrics, trace_path=args.trace,
                    profiler_annotations=args.profile_annotations,
                    timeline=args.timeline is not None,
                    lineage=args.lineage,
                    regret_every=args.regret_every)
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=args.max_batch,
                 max_prompt_len=args.prompt_len,
                 max_new_tokens=args.new_tokens,
                 sampling=SamplingParams(greedy=args.greedy),
                 chunk_size=args.chunk, token_budget=args.token_budget,
                 prefix_sharing=not args.no_prefix_sharing, obs=obs,
                 tp=args.tp)

    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab_size,
                          size=min(args.shared_prefix, args.prompt_len - 1))
    for _ in range(args.requests):
        n = int(rng.integers(args.prompt_len // 2, args.prompt_len))
        tail = rng.integers(0, cfg.vocab_size, size=max(n - len(shared), 1))
        eng.submit(np.concatenate([shared, tail]).astype(np.int32))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"policy={args.policy} budget={args.budget} page={args.page}")
    print(f"finished {len(done)} requests, {s.tokens_generated} tokens "
          f"in {dt:.1f}s ({s.tokens_generated/dt:.1f} tok/s incl. compile)")
    print(f"decode-only throughput: {s.decode_tok_per_s:.1f} tok/s; "
          f"steps={s.steps}; programs={eng.num_compiled_programs()}")
    if args.tp > 1:
        pb = eng.pool_bytes()
        print(f"tp={args.tp}: pool payload {pb['payload_total'] / 1e6:.2f} MB"
              f" total, {pb['per_device_max'] / 1e6:.2f} MB max/device "
              f"across {pb['devices']} devices")
    if s.shared_prefix_hits:
        print(f"prefix sharing: {s.shared_prefix_hits} adoptions, "
              f"{s.shared_prefix_tokens} prompt tokens skipped; "
              f"pool={eng.pool_stats()}")
    ttfts = [r.ttft for r in done if r.ttft > 0]
    if ttfts:
        print(f"ttft: mean={1e3 * np.mean(ttfts):.1f}ms "
              f"max={1e3 * np.max(ttfts):.1f}ms (chunk={args.chunk})")
    if args.timeline:
        n = eng.export_timeline(args.timeline)
        print(f"wrote {args.timeline} ({n} timeline events)")
    if args.lineage and eng.obs.ledger is not None:
        led = eng.obs.ledger
        print(f"lineage: {led.counts()}")
        for slot in range(args.max_batch):
            rep = led.request_loss_report(slot)
            if rep["pages_lost"]:
                score = rep["mean_evict_score"]
                print(f"  slot {slot}: lost {rep['pages_lost']} pages / "
                      f"{rep['tokens_lost']} tokens at {rep['positions']} "
                      f"(mean victim score "
                      f"{'n/a' if score is None else format(score, '.3g')})")
    if args.regret_every:
        for req in done:
            summ = req.regret_summary()
            if summ:
                print(f"  req {req.request_id}: {summ['probes']} probes, "
                      f"divergence mean={summ['mean_divergence']:.3g} "
                      f"max={summ['max_divergence']:.3g}, evicted mass "
                      f"mean={summ['mean_evicted_mass']:.3g}")
    eng.close()
    if not args.no_metrics:
        print(eng.obs.registry.render())
    if args.snapshot:
        with open(args.snapshot, "w") as f:
            json.dump(eng.metrics_snapshot(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.snapshot}")
    if args.trace:
        print(f"wrote {args.trace} ({eng.obs.writer.events_written} events)")


if __name__ == "__main__":
    main()
