"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, layout: str = "2d"):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
    axis crosses the (slow) inter-pod links, so shardings fold it into the
    data-parallel dimension (DESIGN.md §5).

    ``layout="ep"``: the same chips factored as (data, expert, tp) =
    (16, 8, 2) — expert-parallel MoE (experts live on the "expert" axis,
    tokens move via all-to-all; expert-internal d_ff splits over "tp").
    Non-MoE weights shard over the combined ("expert","tp") 16-way axes, so
    dense layers are unchanged."""
    if layout == "ep":
        shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
        axes = ("pod", "data", "expert", "tp") if multi_pod else \
            ("data", "expert", "tp")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_tp_mesh(tp: int):
    """Serving tensor-parallel mesh: (1, tp) with axes ("data", "model").

    The size-1 data axis is kept (rather than a model-only mesh) so every
    sharding helper that asks for batch axes keeps resolving; the engine's
    shard_map runs manual over both axes (DESIGN.md §11). Requires at
    least ``tp`` visible devices — CPU CI forces 4 via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before the
    first jax import."""
    ndev = len(jax.devices())
    if ndev < tp:
        raise ValueError(
            f"tp={tp} needs {tp} devices, found {ndev} (on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} before "
            f"importing jax)")
    return jax.make_mesh((1, tp), ("data", "model"))


# --- hardware constants (TPU v5e; roofline denominators) --------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
