"""Roofline analysis from compiled dry-run artifacts.

Sources:
  compiled.cost_analysis()  -> HLO flops / bytes accessed (per device — the
                               partitioned module is what is analyzed)
  compiled.as_text()        -> post-SPMD optimized HLO; collective bytes are
                               summed from result types of all-gather /
                               all-reduce / reduce-scatter / all-to-all /
                               collective-permute ops with ring-traffic
                               factors (see _RING_FACTORS below).

Terms (seconds), per the assignment:
  compute    = flops_per_device / PEAK_FLOPS_BF16
  memory     = bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


# ---------------------------------------------------------------------------
# jaxpr-based flop/byte counting (trip-count aware)
#
# XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
# count, which silently undercounts every scanned-layer model. The closed
# jaxpr preserves `length` on scan primitives, so this walker multiplies
# nested bodies correctly. flops: dot_general exact (2*M*N*K*batch), other
# ops ~1 flop/output element. bytes: operand+result sizes per op — an
# unfused upper bound on HBM traffic (fusion lowers it; relative ordering
# of the roofline terms is what matters).
# ---------------------------------------------------------------------------

def _aval_bytes(aval) -> int:
    try:
        return int(aval.size) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    a = eqn.invars[0].aval
    b = eqn.invars[1].aval
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    contract = 1
    for d in lc:
        contract *= a.shape[d]
    m = 1
    for d in range(a.ndim):
        if d not in lc and d not in lb:
            m *= a.shape[d]
    n = 1
    for d in range(b.ndim):
        if d not in rc and d not in rb:
            n *= b.shape[d]
    return 2.0 * batch * m * n * contract


def _jaxpr_cost(jaxpr) -> tuple[float, float]:
    """Returns (flops, bytes) for one execution of `jaxpr` (open jaxpr)."""
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub = None
        mult = 1.0
        if prim == "scan":
            sub = eqn.params["jaxpr"].jaxpr
            mult = float(eqn.params["length"])
        elif prim == "shard_map":
            # body shapes are PER-SHARD: scale by the manual shard count to
            # keep the global-flops convention
            cj = eqn.params["jaxpr"]
            sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
            mesh = eqn.params["mesh"]
            mult = 1.0
            for ax in eqn.params.get("manual_axes", ()):  # frozenset of names
                mult *= float(mesh.shape[ax])
        elif prim == "while":
            sub = eqn.params["body_jaxpr"].jaxpr      # trip count unknown: x1
        elif prim == "cond":
            f, b_ = 0.0, 0.0
            for br in eqn.params["branches"]:
                bf, bb = _jaxpr_cost(br.jaxpr)
                f, b_ = max(f, bf), max(b_, bb)
            flops += f
            byts += b_
            continue
        elif "jaxpr" in eqn.params:
            cj = eqn.params["jaxpr"]       # ClosedJaxpr OR open Jaxpr (remat2)
            sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
        elif "call_jaxpr" in eqn.params:
            cj = eqn.params["call_jaxpr"]
            sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
        elif "fun_jaxpr" in eqn.params:    # custom_jvp/vjp calls
            cj = eqn.params["fun_jaxpr"]
            sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
        if sub is not None:
            sf, sb = _jaxpr_cost(sub)
            flops += mult * sf
            byts += mult * sb
            continue
        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        in_b = sum(_aval_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval"))
        if prim in ("scatter", "scatter-add", "scatter_add", "scatter_mul",
                    "scatter_min", "scatter_max", "dynamic_update_slice"):
            # in-place update: traffic = updates + indices (+ result slice),
            # NOT the whole (aliased) operand
            upd = sum(_aval_bytes(v.aval) for v in eqn.invars[1:]
                      if hasattr(v, "aval"))
            byts += 2 * upd
        elif prim in ("gather", "dynamic_slice"):
            idx = sum(_aval_bytes(v.aval) for v in eqn.invars[1:]
                      if hasattr(v, "aval"))
            byts += 2 * out_b + idx
        else:
            byts += in_b + out_b
        if prim == "dot_general":
            flops += _dot_flops(eqn)
        elif prim in ("conv_general_dilated",):
            flops += 2.0 * sum(_aval_bytes(v.aval) / max(v.aval.dtype.itemsize, 1)
                               for v in eqn.outvars)  # rough
        else:
            flops += sum(int(v.aval.size) for v in eqn.outvars
                         if hasattr(v, "aval"))
    return flops, byts


def jaxpr_cost(closed_jaxpr) -> tuple[float, float]:
    """(total flops, total bytes) for a ClosedJaxpr — trip-count aware."""
    return _jaxpr_cost(closed_jaxpr.jaxpr)

# ring-collective traffic per device, as a multiple of the RESULT size
# (N = participant count; factors below use (N-1)/N ~= 1 for N >= 8):
#   all-gather      result is the full tensor; each device receives ~result
#   all-reduce      reduce-scatter + all-gather: ~2x tensor
#   reduce-scatter  each device sends ~full input = result * N
#   all-to-all      ~result
#   collective-permute  result
_COLL_RE = re.compile(
    r"=\s*(?:\(?)((?:\w+\[[0-9,]*\][^ ]*(?:,\s*)?)+)\)?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,N] iota form: N participants per group
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)        # op -> count
    result_bytes: dict = field(default_factory=dict)  # op -> sum result bytes
    traffic_bytes: float = 0.0                        # ring-model bytes/device


def parse_collectives(hlo_text: str, default_group: int = 16) -> CollectiveStats:
    stats = CollectiveStats()
    seen_start = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with -start; count once
        op = m.group(2)
        rbytes = _type_bytes(m.group(1))
        n = _group_size(line, default_group)
        frac = (n - 1) / n if n > 1 else 0.0
        if op == "all-gather":
            traffic = rbytes * frac
        elif op == "all-reduce":
            traffic = 2 * rbytes * frac
        elif op == "reduce-scatter":
            traffic = rbytes * n * frac
        elif op == "all-to-all":
            traffic = rbytes * frac
        else:  # collective-permute
            traffic = rbytes
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.result_bytes[op] = stats.result_bytes.get(op, 0) + rbytes
        stats.traffic_bytes += traffic
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    policy: str
    kind: str
    chips: int
    flops_per_device: float        # jaxpr-derived (trip-count aware) / chips
    bytes_per_device: float        # jaxpr-derived unfused bound / chips
    xla_flops_per_device: float    # compiled cost_analysis (scans counted x1)
    xla_bytes_per_device: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_flops_ratio: float      # MODEL_FLOPS / (flops_per_device * chips)
    collective_counts: dict
    memory_analysis: dict
    compile_seconds: float
    notes: str = ""

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.policy} | "
                f"{self.compute_s:.3e} | {self.memory_s:.3e} | "
                f"{self.collective_s:.3e} | {self.dominant} | "
                f"{self.useful_flops_ratio:.2f} |")


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, policy: str,
            kind: str, chips: int, model_flops: float, compile_seconds: float,
            default_group: int = 16, notes: str = "",
            jaxpr_flops: float | None = None,
            jaxpr_bytes: float | None = None) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):       # some backends return [dict]
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    # jaxpr numbers are GLOBAL; assume even sharding across chips
    flops = (jaxpr_flops / chips) if jaxpr_flops else xla_flops
    byts = (jaxpr_bytes / chips) if jaxpr_bytes else xla_bytes
    coll = parse_collectives(compiled.as_text(), default_group)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = coll.traffic_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total = flops * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, policy=policy, kind=kind,
        chips=chips, flops_per_device=flops, bytes_per_device=byts,
        xla_flops_per_device=xla_flops, xla_bytes_per_device=xla_bytes,
        collective_bytes=coll.traffic_bytes, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, dominant=dominant,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total) if total else 0.0,
        collective_counts={k: [coll.counts[k], coll.result_bytes[k]]
                           for k in coll.counts},
        memory_analysis=mem, compile_seconds=compile_seconds, notes=notes)


def save_roofline(path: str, r: Roofline) -> None:
    with open(path, "w") as f:
        json.dump(asdict(r), f, indent=1)


def model_flops_estimate(cfg, shape_cfg) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch
    tokens per step."""
    n = cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.seq_len * shape_cfg.global_batch
        return 6.0 * n * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.seq_len * shape_cfg.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape_cfg.global_batch      # decode: one token/request
