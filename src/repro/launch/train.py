"""Training driver.

CPU-scale (this container):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 50 --batch 8 --seq 128

Production mesh (real TPU pod): drop --reduced, pass --mesh single|multi;
the same code path pjit-shards params/opt/batch per repro.sharding.rules.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.multimodal import make_inputs
from repro.models.transformer import init_model
from repro.sharding import rules
from repro.training import (
    AdamWConfig,
    DataConfig,
    init_adamw,
    lm_batch,
    make_train_step,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-scale) variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--mesh", default=None, choices=(None, "single", "multi"),
                    help="production mesh (requires matching device count)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps)

    key = jax.random.PRNGKey(args.seed)
    params = init_model(key, cfg)
    opt = init_adamw(params)

    step_fn = make_train_step(cfg, opt_cfg)
    if args.mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        p_sh = rules.param_shardings(mesh, cfg, jax.eval_shape(lambda: params))
        o_sh = rules.opt_shardings(mesh, cfg, jax.eval_shape(lambda: opt), p_sh)
        jstep = jax.jit(lambda p, o, b: step_fn(p, o, b),
                        in_shardings=(p_sh, o_sh, None),
                        out_shardings=(p_sh, o_sh, None),
                        donate_argnums=(0, 1))
    else:
        jstep = jax.jit(lambda p, o, b: step_fn(p, o, b), donate_argnums=(0, 1))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch, seed=args.seed)
    cond = None
    if cfg.cross_attention:
        cond = make_inputs(jax.random.PRNGKey(1), cfg, args.batch, 4)["cond"]
        step_fn_c = make_train_step(cfg, opt_cfg)
        jstep = jax.jit(lambda p, o, b: step_fn_c(p, o, b, cond=cond),
                        donate_argnums=(0, 1))

    t0 = time.perf_counter()
    for i in range(args.steps):
        b = {k: jnp.asarray(v)
             for k, v in lm_batch(dcfg, i, num_codebooks=cfg.num_codebooks).items()}
        params, opt, m = jstep(params, opt, b)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"({(time.perf_counter()-t0):.1f}s)")
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, i + 1,
                                   {"params": params, "opt": opt})
            print(f"  checkpoint -> {path}")
    print(f"done: {args.steps} steps in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
