import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: dump the largest collectives (shape, dtype, group) from
a compiled (arch x shape x mesh x policy) combination — the 'profile' the
§Perf hillclimb iterates against (no real TPU: the lowered IR is the trace).

    PYTHONPATH=src python -m repro.launch.inspect_collectives \
        --arch mixtral-8x7b --shape train_4k --top 15
"""

import argparse
import re

from repro.launch.analysis import _COLL_RE, _group_size, _type_bytes


def collective_lines(hlo_text: str, top: int = 20):
    rows = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(2)
        b = _type_bytes(m.group(1))
        g = _group_size(line, 16)
        name = line.strip().split(" = ")[0][-60:]
        rows.append((b, op, g, m.group(1)[:60], name))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--budget", type=int, default=4096)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    from repro.launch.dryrun import build_lowerable, default_policy
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    pol = args.policy or default_policy(args.shape)
    with mesh:
        jfn, fargs = build_lowerable(args.arch, args.shape, mesh, pol,
                                     args.budget, args.page, args.zero1)
        compiled = jfn.lower(*fargs).compile()
    txt = compiled.as_text()
    print(f"== top collectives: {args.arch} x {args.shape} x {args.mesh} "
          f"x {pol} ==")
    for b, op, g, ty, name in collective_lines(txt, args.top):
        print(f"  {b / 1e9:8.2f} GB  {op:18s} group={g:3d}  {ty}  {name}")


if __name__ == "__main__":
    main()
