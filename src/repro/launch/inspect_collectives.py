import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Dump the collective set (op, group, dtype, bytes) of a compiled program.

Two modes:

**Serving** (``--serve-tp N``): lower the engine's actual tensor-parallel
unified step — the shard_map'd ``Engine._step_impl`` over the (1, N) serving
mesh (DESIGN.md §11) — for both the mixed/prefill program (T = chunk) and
the decode-only program (T = 1), and print every psum/all-gather XLA emitted.
``--json FILE`` writes the set as a stable artifact so CI can diff it: the
sharded step must stay all-reduce-only (no all-gathers, no all-to-alls —
those would mean a spec regression reassembling the pool or the logits).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.inspect_collectives \\
        --arch gemma3-27b --serve-tp 4 --json /tmp/collectives.json

**Dry-run** (``--shape``): the original production-mesh profiler — dump the
largest collectives from a compiled (arch x shape x mesh x policy)
combination, the 'profile' the §Perf hillclimb iterates against.

    PYTHONPATH=src python -m repro.launch.inspect_collectives \\
        --arch mixtral-8x7b --shape train_4k --top 15
"""

import argparse
import json
from collections import Counter

from repro.launch.analysis import _COLL_RE, _group_size, _type_bytes


def collective_lines(hlo_text: str, top: int = 20):
    rows = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(2)
        b = _type_bytes(m.group(1))
        g = _group_size(line, 16)
        name = line.strip().split(" = ")[0][-60:]
        rows.append((b, op, g, m.group(1)[:60], name))
    rows.sort(reverse=True)
    return rows[:top]


def collective_set(hlo_text: str, default_group: int) -> dict:
    """Regression-able summary: per-op counts and result bytes, plus the
    sorted multiset of (op, group, dtype-shape) signatures. Stable across
    runs of the same build (no SSA names, no ordering dependence)."""
    counts: Counter = Counter()
    result_bytes: Counter = Counter()
    sigs = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(2)
        g = _group_size(line, default_group)
        counts[op] += 1
        result_bytes[op] += _type_bytes(m.group(1))
        sigs.append(f"{op} group={g} {m.group(1)}")
    return {"counts": dict(sorted(counts.items())),
            "result_bytes": dict(sorted(result_bytes.items())),
            "signatures": sorted(sigs)}


def lower_serving_step(arch: str, tp: int, policy: str, budget: int,
                       page: int, use_pallas: bool):
    """Build a reduced serving engine at the requested TP degree and lower
    its shard_map'd unified step for T = chunk (mixed) and T = 1 (decode).
    Returns {program_name: hlo_text}."""
    import jax
    import jax.numpy as jnp

    from repro.configs import CacheConfig, get_arch
    from repro.models.transformer import init_model
    from repro.obs import ObsConfig
    from repro.serving import Engine, SamplingParams

    cfg = get_arch(arch).reduced(tp=max(tp, 2))
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=2,
                 max_prompt_len=4 * page, max_new_tokens=4,
                 sampling=SamplingParams(greedy=True), chunk_size=2 * page,
                 seed=0, tp=tp, use_pallas=use_pallas, obs=ObsConfig())
    B = eng.max_batch
    key = jax.random.PRNGKey(0)
    texts = {}
    for name, T in (("mixed", eng.chunk_size), ("decode", 1)):
        args = (eng.params, jnp.zeros((B, T), jnp.int32),
                jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool),
                jnp.zeros((B,), bool), jnp.zeros((B,), bool),
                jnp.full((B,), -1, jnp.int32), jnp.zeros((B,), jnp.int32),
                eng.cache, key)
        texts[name] = eng._step_fn.lower(*args).compile().as_text()
    eng.close()
    return texts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="dry-run mode: production-mesh shape name")
    ap.add_argument("--serve-tp", type=int, default=0, metavar="N",
                    help="serving mode: lower the engine's unified step "
                         "shard_map'd at tp=N and print its collectives")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--budget", type=int, default=4096)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write the collective set here (regression diff)")
    args = ap.parse_args()

    if bool(args.shape) == bool(args.serve_tp):
        ap.error("exactly one of --shape (dry-run) or --serve-tp (serving) "
                 "is required")

    if args.serve_tp:
        pol = args.policy or "paged_eviction"
        budget = args.budget if args.budget != 4096 else 32
        texts = lower_serving_step(args.arch, args.serve_tp, pol, budget,
                                   args.page if args.page != 16 else 4,
                                   args.use_pallas)
        out = {}
        for name, txt in texts.items():
            cs = collective_set(txt, args.serve_tp)
            out[name] = cs
            print(f"== serving step collectives: {args.arch} tp={args.serve_tp}"
                  f" x {pol} x {name} ==")
            if not cs["signatures"]:
                print("  (none)")
            for sig in cs["signatures"]:
                print(f"  {sig}")
            print(f"  totals: {cs['counts']} result_bytes="
                  f"{cs['result_bytes']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"arch": args.arch, "tp": args.serve_tp,
                           "policy": pol, "programs": out},
                          f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"wrote {args.json}")
        return

    from repro.launch.dryrun import build_lowerable, default_policy
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    pol = args.policy or default_policy(args.shape)
    with mesh:
        jfn, fargs = build_lowerable(args.arch, args.shape, mesh, pol,
                                     args.budget, args.page, args.zero1)
        compiled = jfn.lower(*fargs).compile()
    txt = compiled.as_text()
    print(f"== top collectives: {args.arch} x {args.shape} x {args.mesh} "
          f"x {pol} ==")
    for b, op, g, ty, name in collective_lines(txt, args.top):
        print(f"  {b / 1e9:8.2f} GB  {op:18s} group={g:3d}  {ty}  {name}")


if __name__ == "__main__":
    main()
