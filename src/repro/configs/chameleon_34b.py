"""chameleon-34b [vlm] — early-fusion, VQ image tokens in the text vocab,
QK-norm for training stability. [arXiv:2405.09818]

Backbone only: the VQ-GAN image tokenizer is a stub frontend; image tokens
arrive as ordinary token ids / precomputed embeddings (early fusion means
the decoder is modality-agnostic — exactly why PagedEviction applies
unchanged to its KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    source="arXiv:2405.09818 (Chameleon)",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    rope_theta=10_000.0,
    modality="vlm",
    norm="rmsnorm",
    act="silu",
)
