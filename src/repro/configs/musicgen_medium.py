"""musicgen-medium [audio] — decoder-only over EnCodec residual-VQ tokens
(4 codebooks, delay pattern), cross-attention to text conditioning.
[arXiv:2306.05284]

Backbone only: the EnCodec tokenizer and T5 text encoder are stub
frontends; ``input_specs`` supplies codebook token ids and precomputed
conditioning embeddings. Self-attention KV cache is evictable; the
cross-attention KV over the (static) conditioning is exempt.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    source="arXiv:2306.05284 (MusicGen)",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    modality="audio",
    num_codebooks=4,
    cross_attention=True,
    cond_len=64,
    rope_theta=10_000.0,
    norm="layernorm",
    act="gelu",
)
