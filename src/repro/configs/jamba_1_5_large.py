"""jamba-1.5-large-398b [hybrid] — Mamba + attention at 1:7 interleave,
MoE (16 experts, top-2) every other layer. [arXiv:2403.19887]

Layer pattern (period 8): layer 0 = attention, layers 1..7 = Mamba;
MoE MLP on every 2nd layer. PagedEviction applies only to the attention
layers' KV cache; Mamba layers hold O(1) recurrent state (see DESIGN.md
§Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887 (Jamba)",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_every=2,
    attn_every=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    norm="rmsnorm",
    act="silu",
)
