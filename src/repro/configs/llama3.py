"""The paper's own evaluation models: Llama-3.2-1B/3B and Llama-3.1-8B
Instruct. [hf:meta-llama/Llama-3.1-8B-Instruct & Llama-3.2 model cards]"""
from repro.configs.base import ModelConfig

LLAMA_3_2_1B = ModelConfig(
    name="llama-3.2-1b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.2-1B-Instruct",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)

LLAMA_3_2_3B = ModelConfig(
    name="llama-3.2-3b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.2-3B-Instruct",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)

LLAMA_3_1_8B = ModelConfig(
    name="llama-3.1-8b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.1-8B-Instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    norm="rmsnorm",
    act="silu",
)
