"""gemma3-27b [dense] — 5 local (sliding 1024) : 1 global interleave, 128k
context, huge vocab, logit soft-capping. [hf:google/gemma-3-1b-pt family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt (family card; assigned dims)",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    local_window=1024,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
    qk_norm=True,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
)
