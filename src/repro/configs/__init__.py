"""Config registry: 10 assigned architectures + the paper's own Llama trio,
4 assigned input shapes, and the paper's cache/eviction knobs."""
from repro.configs.base import (
    CacheConfig,
    LayerSpec,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.chameleon_34b import CONFIG as CHAMELEON_34B
from repro.configs.stablelm_3b import CONFIG as STABLELM_3B
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.mistral_nemo_12b import CONFIG as MISTRAL_NEMO_12B
from repro.configs.jamba_1_5_large import CONFIG as JAMBA_1_5_LARGE
from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from repro.configs.xlstm_1_3b import CONFIG as XLSTM_1_3B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.llama3 import LLAMA_3_1_8B, LLAMA_3_2_1B, LLAMA_3_2_3B

# The 10 assigned architectures (``--arch <id>``).
ASSIGNED_ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        QWEN2_5_3B,
        CHAMELEON_34B,
        STABLELM_3B,
        MIXTRAL_8X22B,
        MISTRAL_NEMO_12B,
        JAMBA_1_5_LARGE,
        GEMMA3_27B,
        MIXTRAL_8X7B,
        XLSTM_1_3B,
        MUSICGEN_MEDIUM,
    )
}

# Paper's own evaluation models (LongBench / throughput experiments).
PAPER_ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (LLAMA_3_2_1B, LLAMA_3_2_3B, LLAMA_3_1_8B)
}

ARCHS: dict[str, ModelConfig] = {**ASSIGNED_ARCHS, **PAPER_ARCHS}


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; available: {sorted(SHAPES)}") from None


__all__ = [
    "ARCHS",
    "ASSIGNED_ARCHS",
    "PAPER_ARCHS",
    "SHAPES",
    "CacheConfig",
    "LayerSpec",
    "ModelConfig",
    "ShapeConfig",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_arch",
    "get_shape",
]
