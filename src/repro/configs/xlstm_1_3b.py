"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]); attention-free,
constant-size recurrent memory. [arXiv:2405.04517]

PagedEviction is inapplicable (no KV cache exists); the arch is still a
first-class config: training via scan, decode via O(1) state updates
(see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="arXiv:2405.04517 (xLSTM)",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,                      # xLSTM blocks carry their own projections
    vocab_size=50304,
    slstm_every=8,               # 7 mLSTM : 1 sLSTM
    xlstm_proj_factor=2.0,
    norm="layernorm",
    act="gelu",
)
