"""Model / shape / serving configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``.  A config is
pure data: the model substrate (``repro.models``) interprets it.  Layer
heterogeneity (local/global attention, mamba/attention hybrids, MoE-every-k,
sLSTM/mLSTM interleave) is expressed as a repeating *layer pattern* so the
transformer stack can ``lax.scan`` over pattern repetitions with stacked
parameters (lowering cost O(pattern period), not O(num_layers)).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Per-layer spec (one element of the repeating pattern)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    """Static description of a single decoder layer."""
    mixer: str = "attn"          # "attn" | "mamba" | "mlstm" | "slstm"
    attn_kind: str = "global"    # "global" | "local" | "swa"  (attn only)
    mlp: str = "dense"           # "dense" | "moe" | "none"

    @property
    def has_kv_cache(self) -> bool:
        return self.mixer == "attn"


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (the public pool entries)."""
    name: str
    arch_type: str               # dense | moe | hybrid | ssm | vlm | audio
    source: str                  # citation (paper / model card)
    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # >0: SWA window for attn_kind=="swa"
    local_window: int = 0        # >0: window for attn_kind=="local"
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global
    logit_soft_cap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_every: int = 1           # MoE MLP on every k-th layer (jamba: 2)
    moe_capacity_factor: float = 1.25

    # --- hybrid (jamba) -----------------------------------------------------
    attn_every: int = 0          # >0: attention on layer i%attn_every==0, rest mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0       # 0 -> ceil(d_model / 16)

    # --- xLSTM ---------------------------------------------------------------
    slstm_every: int = 0         # >0: sLSTM on layer i%slstm_every==slstm_every-1
    xlstm_proj_factor: float = 2.0

    # --- modality ------------------------------------------------------------
    modality: str = "text"       # text | vlm | audio
    num_codebooks: int = 1       # musicgen: parallel codebooks
    cross_attention: bool = False
    cond_len: int = 0            # conditioning sequence length (stub frontend)

    # --- misc -----------------------------------------------------------------
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ props
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, -(-self.d_model // 16))

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    # ------------------------------------------------------------- pattern
    def layer_pattern(self) -> list[LayerSpec]:
        """The repeating per-layer pattern (period P)."""
        period = 1
        if self.attn_every:
            period = max(period, self.attn_every)
        if self.slstm_every:
            period = max(period, self.slstm_every)
        if self.local_global_ratio:
            period = max(period, self.local_global_ratio + 1)
        if self.num_experts and self.moe_every > 1:
            period = max(period, self.moe_every)
        # lcm-ish: all our configs use compatible periods; verify below.
        specs = []
        for i in range(period):
            if self.attn_every:
                mixer = "attn" if i % self.attn_every == 0 else "mamba"
            elif self.slstm_every:
                mixer = "slstm" if i % self.slstm_every == self.slstm_every - 1 else "mlstm"
            else:
                mixer = "attn"
            if mixer == "attn":
                if self.local_global_ratio:
                    # gemma3 style: ratio local layers then 1 global per period slot
                    attn_kind = "global" if (i + 1) % (self.local_global_ratio + 1) == 0 else "local"
                elif self.sliding_window:
                    attn_kind = "swa"
                else:
                    attn_kind = "global"
            else:
                attn_kind = "global"
            if self.num_experts and i % self.moe_every == (self.moe_every - 1):
                mlp = "moe"
            elif mixer in ("mlstm", "slstm"):
                mlp = "none"          # xLSTM blocks carry their own projections
            else:
                mlp = "dense"
            specs.append(LayerSpec(mixer=mixer, attn_kind=attn_kind, mlp=mlp))
        return specs

    def layer_specs(self) -> list[LayerSpec]:
        """Per-layer specs for the full depth (pattern repeated + remainder)."""
        pat = self.layer_pattern()
        reps, rem = divmod(self.num_layers, len(pat))
        return pat * reps + pat[:rem]

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern())

    @property
    def full_pattern_reps(self) -> int:
        return self.num_layers // self.pattern_period

    @property
    def remainder_layers(self) -> int:
        return self.num_layers % self.pattern_period

    def num_attn_layers(self) -> int:
        return sum(1 for s in self.layer_specs() if s.mixer == "attn")

    # ------------------------------------------------------------ parameter math
    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        hd = self.resolved_head_dim
        total = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        if self.num_codebooks > 1:
            total += (self.num_codebooks - 1) * self.vocab_size * self.d_model * 2
        for spec in self.layer_specs():
            if spec.mixer == "attn":
                q = self.d_model * self.num_heads * hd
                kv = 2 * self.d_model * self.num_kv_heads * hd
                o = self.num_heads * hd * self.d_model
                total += q + kv + o
                if self.cross_attention:
                    total += q + kv + o
            elif spec.mixer == "mamba":
                di, ds, dr = self.mamba_d_inner, self.mamba_d_state, self.resolved_dt_rank
                total += self.d_model * di * 2          # in_proj
                total += di * self.mamba_d_conv          # conv
                total += di * (dr + 2 * ds)              # x_proj
                total += dr * di + di * ds + di          # dt_proj, A, D
                total += di * self.d_model               # out_proj
            elif spec.mixer in ("mlstm", "slstm"):
                di = int(self.xlstm_proj_factor * self.d_model)
                if spec.mixer == "mlstm":
                    total += self.d_model * di * 2 + 3 * di * di // max(1, self.num_heads) + di * self.d_model
                else:
                    total += 4 * self.d_model * self.d_model + 4 * self.d_model * self.d_model // max(1, self.num_heads)
                    total += self.d_model * di * 2
            if spec.mlp == "dense":
                total += 3 * self.d_model * self.d_ff
            elif spec.mlp == "moe":
                total += self.d_model * self.num_experts  # router
                total += self.num_experts * 3 * self.d_model * self.d_ff
            total += 2 * self.d_model  # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        total = self.param_count()
        moe_layers = sum(1 for s in self.layer_specs() if s.mlp == "moe")
        unused = (self.num_experts - self.num_experts_per_tok) * 3 * self.d_model * self.d_ff
        return total - moe_layers * unused

    # --------------------------------------------------------------- reduced
    def reduced(self, tp: int = 1) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (spec: <=2-ish layers,
        d_model<=512, <=4 experts). Keeps one full pattern period when the
        family is heterogeneous so the interleave is exercised.

        ``tp``: make the reduced config servable at that tensor-parallel
        degree — KV heads are rounded UP to a multiple of ``tp`` (preserving
        the family's GQA ratio for the query heads), since TP shards whole
        KV heads. TP∈{1,2,4} parity tests must use the SAME tp-capable
        config at every degree."""
        num_layers = 2
        if self.attn_every or self.slstm_every or self.local_global_ratio:
            num_layers = min(self.pattern_period, 4)
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        # keep GQA ratio when possible
        if self.num_kv_heads < self.num_heads:
            kv = max(1, heads // self.q_per_kv)
        if tp > 1:
            kv = -(-kv // tp) * tp
            # keep a GQA fold (G=2) when the family has one, but cap it so
            # tp=4 configs stay CPU-smoke sized
            ratio = 2 if self.num_kv_heads < self.num_heads else 1
            heads = kv * ratio
        overrides = dict(
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            local_window=min(self.local_window, 64) if self.local_window else 0,
            cond_len=min(self.cond_len, 8) if self.cond_len else 0,
            dtype="float32",
        )
        if self.num_experts:
            overrides["num_experts"] = min(self.num_experts, 4)
            overrides["num_experts_per_tok"] = min(self.num_experts_per_tok, 2)
        if self.attn_every:
            overrides["attn_every"] = min(self.attn_every, num_layers)
            overrides["moe_every"] = min(self.moe_every, 2)
        if self.slstm_every:
            overrides["slstm_every"] = min(self.slstm_every, num_layers)
        if self.local_global_ratio:
            overrides["local_global_ratio"] = min(self.local_global_ratio, num_layers - 1)
        return replace(self, **overrides)

    def validate(self) -> None:
        assert self.num_heads % self.num_kv_heads == 0, self.name
        assert self.d_model > 0 and self.num_layers > 0
        if self.num_experts:
            assert self.num_experts_per_tok > 0
        if self.attn_every:
            assert self.num_layers % self.pattern_period == 0 or True
        # pattern must tile
        assert len(self.layer_specs()) == self.num_layers


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


# ---------------------------------------------------------------------------
# Serving / cache configuration (paper knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheConfig:
    """Paged KV cache + eviction configuration (the paper's knobs)."""
    page_size: int = 16              # B in the paper (16 optimal per vLLM)
    cache_budget: int = 1024         # C in the paper (256..4096 evaluated)
    policy: str = "paged_eviction"   # paged_eviction | streaming_llm |
                                     # inverse_key_l2 | keydiff | full
    num_sink_tokens: int = 4         # streaming_llm attention sinks
    protect_recent: bool = False     # optional extension: never evict newest page
    dtype: str = "bfloat16"
    slab_multiple: int = 1           # round page slabs up to a multiple (TPU:
                                     # 16 enables sharding the page dim over
                                     # the model axis — decode context
                                     # parallelism; see sharding.rules)

    @property
    def budget_pages(self) -> int:
        assert self.cache_budget % self.page_size == 0, (
            f"budget {self.cache_budget} must be a multiple of page {self.page_size}")
        return self.cache_budget // self.page_size

    def max_pages(self, seq_len: int) -> int:
        """Physical pages per request. Full cache: covers seq_len; eviction
        policies: statically bounded by the budget (+1 working page)."""
        total = -(-seq_len // self.page_size)
        if self.policy == "full":
            return total
        return min(total, self.budget_pages + 1)

    def validate(self) -> None:
        assert self.page_size > 0
        assert self.cache_budget >= self.page_size
        assert self.cache_budget % self.page_size == 0
