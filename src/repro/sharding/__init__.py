"""Sharding rules for params / caches / batches over the production mesh."""
from repro.sharding.rules import (
    activation_constraint,
    batch_axes,
    cache_shardings,
    data_shardings,
    opt_shardings,
    param_shardings,
    replicated,
)

__all__ = [
    "activation_constraint", "batch_axes", "cache_shardings",
    "data_shardings", "opt_shardings", "param_shardings", "replicated",
]
