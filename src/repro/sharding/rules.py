"""Logical-axis sharding rules (MaxText-style, divisibility-checked).

Rules map parameter/cache/batch leaves to PartitionSpecs by key-path name +
shape. Every rule verifies the dimension divides the mesh axis size and
falls back to replication otherwise (GQA kv_heads < model axis, xLSTM's 4
heads, batch=1 long-context decode, ...). The dry-run then reports what the
compiler actually did — the §Perf loop iterates on these rules.

Baseline scheme (documented in DESIGN.md §5):
  batch dims            -> ("pod", "data") when divisible (pod folds into DP)
  attention q heads     -> "model" (head-granular: requires H % model == 0)
  kv heads              -> "model" iff KV % model == 0, else replicated
  ffn hidden / d_inner  -> "model" (Megatron column/row split)
  vocab (embed/head)    -> "model"
  MoE experts           -> tensor-split per expert (d_ff over "model");
                           expert-parallel is the hillclimb variant
  norms, biases, gates  -> replicated
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, *names: str) -> int:
    return int(np.prod([mesh.shape[n] for n in names if n in mesh.shape]))


def batch_axes(mesh: Mesh, batch: int):
    """Largest data-parallel axis tuple that divides ``batch``."""
    if "pod" in mesh.shape and batch % _axis_size(mesh, "pod", "data") == 0:
        return ("pod", "data")
    if batch % _axis_size(mesh, "data") == 0:
        return "data"
    return None


def model_axes(mesh: Mesh):
    """The tensor-parallel axis (or axes): "model" on the standard mesh, the
    combined ("expert","tp") pair on the expert-parallel mesh layout."""
    if "model" in mesh.shape:
        return "model"
    if "expert" in mesh.shape:
        return ("expert", "tp")
    return None


def _ma_size(mesh: Mesh) -> int:
    ma = model_axes(mesh)
    if ma is None:
        return 1
    return _axis_size(mesh, *(ma if isinstance(ma, tuple) else (ma,)))


def _model_ok(mesh: Mesh, dim: int) -> bool:
    m = _ma_size(mesh)
    return m > 1 and dim % m == 0


def _path_str(path) -> str:
    def seg(p):
        for attr in ("key", "idx", "name"):
            v = getattr(p, attr, None)
            if v is not None:
                return str(v)
        return str(p).strip(".")
    return "/".join(seg(p) for p in path)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_spec(mesh: Mesh, cfg, path: str, shape: tuple) -> P:
    m = lambda d: _model_ok(mesh, shape[d])
    name = path.rsplit("/", 1)[-1]
    # strip the stacked-repetition leading dim for pattern slots
    stacked = path.startswith("pattern/")
    off = 1 if stacked and len(shape) > 0 else 0

    def spec(*dims):
        full = (None,) * off + dims
        full = full + (None,) * (len(shape) - len(full))
        return P(*full)

    H, KV = cfg.num_heads, cfg.num_kv_heads
    msz = _ma_size(mesh)
    MA = model_axes(mesh)

    if name in ("embed", "lm_head"):
        # (V, D) or (K, V, D): shard vocab
        vdim = len(shape) - 2
        if _model_ok(mesh, shape[vdim]):
            return P(*([None] * vdim + [MA, None]))
        return P()
    if name in ("wq", "wk", "wv") and "mlstm" in path:
        # mLSTM inner (di, di) projections: row-split — the input xc is
        # di-sharded, so contracting the sharded dim costs one bf16 psum
        # instead of replicated-weight f32 ARs (§Perf xlstm iteration)
        return spec(MA, None) if m(off + 0) else spec()
    if name == "wq":
        return spec(None, MA) if H % msz == 0 and m(off + 1) else spec()
    if name in ("wk", "wv"):
        return spec(None, MA) if KV % msz == 0 and m(off + 1) else spec()
    if name == "wo":
        return spec(MA, None) if H % msz == 0 and m(off + 0) else spec()
    E = cfg.num_experts
    ep = "expert" in mesh.shape and E and E % mesh.shape["expert"] == 0
    if name in ("w_gate", "w_up"):
        if len(shape) - off == 3:      # MoE (E, D, F)
            if ep and shape[off + 2] % mesh.shape["tp"] == 0:
                return spec("expert", None, "tp")   # expert-parallel layout
            return spec(None, None, MA) if m(off + 2) else spec()
        return spec(None, MA) if m(off + 1) else spec()
    if name == "w_down":
        if len(shape) - off == 3:      # MoE (E, F, D)
            if ep and shape[off + 1] % mesh.shape["tp"] == 0:
                return spec("expert", "tp", None)
            return spec(None, MA, None) if m(off + 1) else spec()
        return spec(MA, None) if m(off + 0) else spec()
    if name in ("in_proj", "up_proj", "dt_proj", "w_gates"):
        return spec(None, MA) if m(off + 1) else spec()
    if name in ("out_proj", "down_proj", "x_proj"):
        return spec(MA, None) if m(off + 0) else spec()
    if name == "conv_w":               # (dc, di)
        return spec(None, MA) if m(off + 1) else spec()
    if name in ("A_log",):             # (di, ds)
        return spec(MA, None) if m(off + 0) else spec()
    if name in ("D", "dt_bias", "conv_b"):   # (di,)
        return spec(MA) if m(off + 0) else spec()
    # everything else (norms, biases, router, gates, recurrent mats): replicate
    return P()


def param_shardings(mesh: Mesh, cfg, params_shape) -> Any:
    """PartitionSpec pytree for a params pytree (of arrays or ShapeDtypes)."""
    def rule(path, leaf):
        spec = _param_spec(mesh, cfg, _path_str(path), tuple(leaf.shape))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# cache / state rules
# ---------------------------------------------------------------------------

def _cache_spec(mesh: Mesh, cfg, path: str, shape: tuple, batch: int) -> P:
    b = batch_axes(mesh, batch)
    name = path.rsplit("/", 1)[-1]
    stacked = path.startswith("pattern/")
    off = 1 if stacked else 0
    rest = shape[off:]

    def spec(*dims):
        full = (None,) * off + dims
        full = full + (None,) * (len(shape) - len(full))
        return P(*full)

    msz = _ma_size(mesh)
    MA = model_axes(mesh)
    kv_div = msz > 1 and cfg.num_kv_heads % msz == 0

    def _dp_axes(n: int):
        """DP axes for the POOL dim — divides pool state evenly over the
        data shards. NOTE: the allocator is locality-blind today (lowest
        free index wins), so a request's pages may live on any shard;
        shard-local allocation is future work (DESIGN.md §5)."""
        if "pod" in mesh.shape and n % _axis_size(mesh, "pod", "data") == 0:
            return ("pod", "data")
        if _axis_size(mesh, "data") > 1 and n % _axis_size(mesh, "data") == 0:
            return ("data",)
        return ()

    def _pool_dim0(n: int, take_model: bool):
        """Axes tuple for the pool-page dim: DP axes, optionally folding the
        model axes in (decode context parallelism: each model shard holds
        1/msz of the pool; softmax combines via small collectives). vLLM
        replicates KV when kv < tp — on TPU the pool dim is the better
        axis (DESIGN.md §5)."""
        dp = _dp_axes(n)
        if take_model and msz > 1:
            ma = MA if isinstance(MA, tuple) else (MA,)
            if n % (int(np.prod([mesh.shape[a] for a in dp + ma]))) == 0:
                return dp + ma
        return dp

    def _ax(t):
        return None if not t else (t[0] if len(t) == 1 else t)

    if name in ("k", "v") and len(rest) == 4 and "xattn" not in path:
        # shared page pool (N, page, KV, hd): kv heads over "model" when
        # divisible, else the model axes fold into the pool dim
        d0 = _ax(_pool_dim0(rest[0], take_model=not kv_div))
        return spec(d0, None, MA if kv_div else None, None)
    if name in ("k", "v") and len(rest) == 4:
        # static cross-attn KV (B, Sc, KV, hd)
        return spec(b, None, MA if kv_div else None, None)
    if name in ("k_scale", "v_scale") and len(rest) == 3:
        # (N, page, KV): follow the pool's sharding choice
        d0 = _ax(_pool_dim0(rest[0], take_model=not kv_div))
        return spec(d0, None, MA if kv_div else None)
    if name in ("pos", "score") and len(rest) == 2:
        # (N, page): follow the pool-dim sharding to avoid resharding
        d0 = _ax(_pool_dim0(rest[0], take_model=not kv_div))
        return spec(d0, None)
    if name == "ref_count" and len(rest) == 1:
        return spec(_ax(_pool_dim0(rest[0], take_model=not kv_div)))
    if name == "stats" and len(rest) == 1:
        # (devstats.NSTATS,) telemetry vector: replicate — it is tiny and
        # every shard's mutators contribute (the batch fall-back below
        # would wrongly put batch axes on its only dim)
        return spec(None)
    if name == "block_table" and len(rest) == 2:
        return spec(b, None)
    if name in ("cur_page", "cur_off", "cur_pos"):
        return spec(b)
    if name == "conv":                 # (B, dc-1, di)
        di = rest[2] if len(rest) == 3 else 0
        return spec(b, None, MA if _model_ok(mesh, di) else None)
    if name == "ssm":                  # (B, di, ds)
        return spec(b, MA if _model_ok(mesh, rest[1]) else None, None)
    if name == "C":                    # mLSTM (B, H, hd, hd)
        hd = rest[2]
        return spec(b, None, MA if _model_ok(mesh, hd) else None, None)
    if name == "n" and len(rest) == 3:  # mLSTM normalizer (B, H, hd)
        hd = rest[-1]
        return spec(b, None, MA if _model_ok(mesh, hd) else None)
    if name == "m" and len(rest) == 2 and rest[1] <= 128:  # mLSTM (B, H)
        return spec(b, None)
    if name in ("c", "h", "n", "m") and len(rest) == 2:    # sLSTM (B, D)
        return spec(b, MA if _model_ok(mesh, rest[1]) else None)
    # fall back: shard batch only
    return spec(b)


def cache_shardings(mesh: Mesh, cfg, cache_shape, batch: int) -> Any:
    def rule(path, leaf):
        spec = _cache_spec(mesh, cfg, _path_str(path), tuple(leaf.shape), batch)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(rule, cache_shape)


# ---------------------------------------------------------------------------
# unified-step / chunk-kernel operands
# ---------------------------------------------------------------------------

def step_input_shardings(mesh: Mesh, cfg, batch: int, chunk: int) -> dict:
    """PartitionSpecs for the unified mixed-batch step's operands and the
    paged flash-prefill kernel's tile layouts (DESIGN.md §6):

      tokens / n_tok / masks     (B, T) / (B,)   — batch over DP axes
      share_src / share_pages    (B,)            — prefix-sharing adoption
                                                   operands, batch over DP
      q chunk  (B, T, H, hd)                     — heads over "model" when
                                                   divisible (same split as
                                                   the decode kernel's query
                                                   group), batch over DP
      q_pos    (B, T)                            — batch over DP
      block_table (B, P)                         — batch only (scalar
                                                   prefetch reads it whole)
      page_scores (B, P)                         — fused-epilogue eviction
                                                   scores (kernel byproduct
                                                   consumed host-of-kernel by
                                                   the policies), batch only
      decode_partials (B, KV, S, G, hd)          — split-K un-normalized
                                                   (acc/m/l) flash partials;
                                                   the combine reduction is
                                                   per-(b, kv) so kv heads
                                                   split over "model" when
                                                   divisible
      epilogue_norms (B, KV, P, page)            — kn/vn byproduct outputs,
                                                   same kv-head split

    The pool-side operands (k/v pool, pos) keep the cache rules — the chunk
    kernel streams the same physical tiles the decode kernel does, so no
    resharding happens between mixed and decode-only steps."""
    b = batch_axes(mesh, batch)
    msz = _ma_size(mesh)
    MA = model_axes(mesh)
    heads = MA if (msz > 1 and cfg.num_heads % msz == 0) else None
    kv = MA if (msz > 1 and cfg.num_kv_heads % msz == 0) else None
    return {
        "tokens": P(b, None),
        "n_tok": P(b),
        "mask": P(b),
        "share_src": P(b),
        "share_pages": P(b),
        "q": P(b, None, heads, None),
        "q_pos": P(b, None),
        "block_table": P(b, None),
        "page_scores": P(b, None),
        "decode_partials": P(b, kv, None, None, None),
        "epilogue_norms": P(b, kv, None, None),
    }


# ---------------------------------------------------------------------------
# batch / misc
# ---------------------------------------------------------------------------

def data_shardings(mesh: Mesh, batch_tree) -> Any:
    """Shard every leaf's leading (batch) dim over the DP axes."""
    def rule(leaf):
        b = batch_axes(mesh, leaf.shape[0]) if leaf.ndim else None
        return NamedSharding(mesh, P(*((b,) + (None,) * (leaf.ndim - 1)))
                             if leaf.ndim else P())
    return jax.tree.map(rule, batch_tree)


def replicated(mesh: Mesh, tree) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def opt_shardings(mesh: Mesh, cfg, opt_shape, params_shardings,
                  zero1: bool = False) -> Any:
    """Optimizer moments mirror parameter shardings; step is replicated.

    ``zero1``: additionally shard each moment over the ``data`` axis on the
    first replicated dimension that divides it (ZeRO-1 — the f32 moments are
    the dominant training-memory term for the 100B+ configs)."""
    from repro.training.optimizer import AdamWState

    def zshard(sh_leaf, shape_leaf):
        ndim = len(shape_leaf.shape)
        spec = list(sh_leaf.spec) + [None] * (ndim - len(sh_leaf.spec))
        dsz = _axis_size(mesh, "data")
        for i in range(ndim):
            if spec[i] is None and dsz > 1 and shape_leaf.shape[i] % dsz == 0 \
                    and shape_leaf.shape[i] >= dsz:
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    if not zero1:
        return AdamWState(step=NamedSharding(mesh, P()),
                          mu=params_shardings, nu=params_shardings)
    mu = jax.tree.map(zshard, params_shardings, opt_shape.mu)
    nu = jax.tree.map(zshard, params_shardings, opt_shape.nu)
    return AdamWState(step=NamedSharding(mesh, P()), mu=mu, nu=nu)


def activation_constraint(mesh: Mesh, batch: int, seq_parallel: bool = False):
    """Returns an ``ac`` callable for forward passes: pins layer inputs
    (B, S, D) / (B, D) to batch-sharded, replicated elsewhere (baseline).

    ``seq_parallel``: Megatron-style sequence parallelism — layer inputs
    (B, S, D) additionally shard S over "model". Norms are per-token so the
    sharded region is free; GSPMD materializes the all-gather entering each
    mixer and the reduce-scatter after its output projection (the classic
    AG+RS replacement of the residual-stream ARs), and the remat-saved
    per-rep activations shrink by the model-axis factor.

    The callable also exposes two stronger pins used inside recurrent /
    expert modules, where GSPMD propagation through moveaxis/scan
    boundaries otherwise drops the sharding entirely (measured: a
    replicated (S, B, d_inner) f32 scan input costs 268 GB/device on
    jamba train — §Perf jamba iter 5):

      ac.inner(x)  (B, ..., C) -> batch on dim0, C on "model" if divisible
      ac.time(x)   (S, B, ..., C) -> batch on dim1, C on "model" if divisible
    """
    b = batch_axes(mesh, batch)
    msz = _ma_size(mesh)
    MA = model_axes(mesh)

    def _pin(x, spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def ac(x):
        if seq_parallel and x.ndim >= 3 and msz > 1 and x.shape[1] % msz == 0:
            return _pin(x, P(*((b, MA) + (None,) * (x.ndim - 2))))
        return _pin(x, P(*((b,) + (None,) * (x.ndim - 1))))

    def inner(x):
        last = MA if (msz > 1 and x.shape[-1] % msz == 0) else None
        return _pin(x, P(*((b,) + (None,) * (x.ndim - 2) + (last,))))

    def time(x):
        last = MA if (msz > 1 and x.shape[-1] % msz == 0) else None
        return _pin(x, P(*((None, b) + (None,) * (x.ndim - 3) + (last,))))

    ac.inner = inner
    ac.time = time
    ac.mesh = mesh
    ac.batch_axes = b
    return ac


def pin_inner(ac):
    """Module-side helper: the strong inner pin if ``ac`` provides one."""
    return getattr(ac, "inner", None) or (lambda x: x)


def pin_time(ac):
    return getattr(ac, "time", None) or (lambda x: x)


# ---------------------------------------------------------------------------
# tensor-parallel serving (shard_map manual specs — DESIGN.md §11)
# ---------------------------------------------------------------------------
# Unlike the GSPMD rules above (hints the compiler may override), these are
# the MANUAL partition specs for the serving engine's shard_map'd unified
# step: they are exact contracts — every leaf is either sharded over the
# "model" axis on a named dimension or fully replicated. The deliberate
# differences from ``_param_spec``:
#   * embed / lm_head are REPLICATED (not vocab-sharded): logits are
#     computed whole on every shard so sampling needs no vocab gather, and
#     the replicated PRNG key then samples the identical token everywhere.
#   * every piece of pool METADATA (pos, score, block_table, ref_count,
#     cur_page, cur_off, stats) is replicated, so each shard runs the full
#     allocator/eviction logic and stays bit-identical — only the K/V pool
#     payload (and its int8 scales) splits, over the KV-head dim.

TP_AXIS = "model"


def _tp_stacked_spec(path: str, shape: tuple):
    """Common prelude: (off, spec) honouring the stacked-pattern leading
    repetition dim that pattern-slot leaves carry."""
    off = 1 if path.startswith("pattern/") else 0

    def spec(*dims):
        full = (None,) * off + dims
        full = full + (None,) * (len(shape) - len(full))
        return P(*full)

    return off, spec


def _tp_param_spec(path: str, shape: tuple) -> P:
    name = path.rsplit("/", 1)[-1]
    off, spec = _tp_stacked_spec(path, shape)
    if name in ("wq", "wk", "wv"):
        return spec(None, TP_AXIS)             # column-parallel (head shards)
    if name in ("bq", "bk", "bv"):
        return spec(TP_AXIS)                   # (H*hd,)/(KV*hd,) follow wq/wk
    if name == "wo":
        return spec(TP_AXIS, None)             # row-parallel -> psum
    if name in ("w_gate", "w_up"):
        if len(shape) - off == 3:              # MoE (E, D, F)
            return spec(None, None, TP_AXIS)
        return spec(None, TP_AXIS)             # dense (D, F)
    if name == "w_down":
        if len(shape) - off == 3:              # MoE (E, F, D)
            return spec(None, TP_AXIS, None)
        return spec(TP_AXIS, None)             # dense (F, D) -> psum
    # embed, lm_head, norms, q_norm/k_norm, router: replicated
    return P()


def _tp_cache_spec(path: str, shape: tuple) -> P:
    name = path.rsplit("/", 1)[-1]
    off, spec = _tp_stacked_spec(path, shape)
    rest = shape[off:]
    if name in ("k", "v") and len(rest) == 4 and "xattn" not in path:
        return spec(None, None, TP_AXIS, None)  # pool (N, page, KV, hd)
    if name in ("k_scale", "v_scale") and len(rest) == 3:
        return spec(None, None, TP_AXIS)        # (N, page, KV)
    return P()                                  # metadata: replicated


def tp_param_specs(params) -> Any:
    """PartitionSpec pytree for the serving params under TP shard_map."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _tp_param_spec(_path_str(path), tuple(leaf.shape)),
        params)


def tp_cache_specs(cache) -> Any:
    """PartitionSpec pytree for a ModelCache under TP shard_map."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _tp_cache_spec(_path_str(path), tuple(leaf.shape)),
        cache)


def tp_param_shardings(mesh: Mesh, params) -> Any:
    """NamedSharding pytree (device_put placement) matching tp_param_specs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _tp_param_spec(_path_str(path), tuple(leaf.shape))),
        params)


def tp_cache_shardings(mesh: Mesh, cache) -> Any:
    """NamedSharding pytree (device_put placement) matching tp_cache_specs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _tp_cache_spec(_path_str(path), tuple(leaf.shape))),
        cache)


def validate_tp(cfg, tp: int) -> None:
    """Raise unless the config can shard whole heads/experts at degree
    ``tp``. Reduced configs can be widened with ``cfg.reduced(tp=tp)``."""
    if tp <= 1:
        return
    problems = []
    if cfg.num_heads % tp:
        problems.append(f"num_heads={cfg.num_heads}")
    if cfg.num_kv_heads % tp:
        problems.append(f"num_kv_heads={cfg.num_kv_heads}")
    if cfg.d_ff and cfg.d_ff % tp:
        problems.append(f"d_ff={cfg.d_ff}")
    if problems:
        raise ValueError(
            f"{cfg.name}: {', '.join(problems)} not divisible by tp={tp}; "
            f"TP shards whole KV heads and d_ff columns (use "
            f"cfg.reduced(tp={tp}) for smoke configs)")
    for spec in cfg.layer_specs():
        if spec.mixer != "attn":
            raise ValueError(
                f"{cfg.name}: TP serving only supports attention mixers "
                f"(got {spec.mixer!r}; recurrent state has no KV-head axis)")
    if cfg.cross_attention:
        raise ValueError(f"{cfg.name}: TP serving does not support "
                         "cross-attention caches yet")
