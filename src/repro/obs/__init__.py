"""Serving telemetry (DESIGN.md §9): metrics registry + per-step trace.

Three pieces, deliberately decoupled from each other and from the engine:

- :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket latency
  histograms with real p50/p90/p99, snapshot-able to JSON and renderable
  as a text dashboard.
- :mod:`repro.obs.trace` — buffered per-step JSONL trace (schema +
  validator) and optional ``jax.profiler`` annotation scopes.
- :mod:`repro.core.devstats` — the device half: the int32 stats vector
  the pool mutators accumulate inside the jitted step (no host callbacks
  on the hot path), reconciled into the registry once per step.

``ObsConfig`` is the single knob surface the engine takes; ``EngineObs``
bundles the live registry + writer so ``Engine.step`` carries one handle.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               LATENCY_BOUNDS_S)
from repro.obs.trace import (TRACE_SCHEMA, TRACE_SCHEMA_VERSION, TraceWriter,
                             annotation, validate_event, validate_file)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "LATENCY_BOUNDS_S",
    "TRACE_SCHEMA", "TRACE_SCHEMA_VERSION", "TraceWriter", "annotation",
    "validate_event", "validate_file", "ObsConfig", "EngineObs",
]


@dataclass
class ObsConfig:
    """What the engine should instrument.

    metrics      : host registry + device stats vector (the ≤2%-overhead
                   default-on path — BENCH_obs.json gates it)
    trace_path   : write one JSONL event per step here (None == no trace)
    profiler_annotations : wrap plan/step in jax.profiler.TraceAnnotation
                   scopes (off by default; only useful under a profiler)
    program_ceiling : compiled-program count the engine expects at steady
                   state; crossing it flips the unexpected_compile flag on
                   that step's trace event and bumps the sentinel counter
    """
    metrics: bool = True
    trace_path: str | None = None
    profiler_annotations: bool = False
    program_ceiling: int = 2

    @property
    def enabled(self) -> bool:
        return self.metrics or self.trace_path is not None


@dataclass
class EngineObs:
    """Live telemetry state owned by one Engine."""
    cfg: ObsConfig
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    writer: TraceWriter | None = None

    def __post_init__(self):
        if self.cfg.trace_path and self.writer is None:
            self.writer = TraceWriter(self.cfg.trace_path)

    def close(self) -> None:
        if self.writer is not None:
            self.writer.close()
