"""Serving telemetry + forensics (DESIGN.md §9–§10).

Pieces, deliberately decoupled from each other and from the engine:

- :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket latency
  histograms with real p50/p90/p99, snapshot-able to JSON and renderable
  as a text dashboard.
- :mod:`repro.obs.trace` — buffered JSONL trace (schema v2: step / event /
  probe records + version-dispatched validator) and optional
  ``jax.profiler`` annotation scopes.
- :mod:`repro.core.devstats` — the device half: the int32 stats vector
  the pool mutators accumulate inside the jitted step (no host callbacks
  on the hot path), reconciled into the registry once per step.
- :mod:`repro.obs.timeline` — per-request span timelines exported as
  Chrome-trace/Perfetto JSON (``serve.py --timeline``).
- :mod:`repro.obs.lineage` — host-side page-lineage ledger: every page's
  life, every request's eviction losses, reconciled exactly against
  ``block_table``/``ref_count``.
- :mod:`repro.obs.regret` — sampled eviction-regret shadow probes
  (divergence vs an uncompressed shadow cache + attention mass on evicted
  pages).

``ObsConfig`` is the single knob surface the engine takes; ``EngineObs``
bundles the live registry + writer + forensics state so ``Engine.step``
carries one handle.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               LATENCY_BOUNDS_S)
from repro.obs.trace import (TRACE_SCHEMA, TRACE_SCHEMA_V1,
                             TRACE_SCHEMA_VERSION, TraceWriter, annotation,
                             validate_event, validate_file)
from repro.obs.timeline import TimelineRecorder
from repro.obs.lineage import PageLineageLedger, StepPlanContext

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "LATENCY_BOUNDS_S",
    "TRACE_SCHEMA", "TRACE_SCHEMA_V1", "TRACE_SCHEMA_VERSION", "TraceWriter",
    "annotation", "validate_event", "validate_file", "ObsConfig",
    "EngineObs", "TimelineRecorder", "PageLineageLedger", "StepPlanContext",
]


@dataclass
class ObsConfig:
    """What the engine should instrument.

    metrics      : host registry + device stats vector (the ≤2%-overhead
                   default-on path — BENCH_obs.json gates it)
    trace_path   : write one JSONL record per step here (None == no trace);
                   lineage events and regret probes also land on this
                   stream when enabled
    profiler_annotations : wrap plan/step in jax.profiler.TraceAnnotation
                   scopes (off by default; only useful under a profiler)
    program_ceiling : compiled-program count the engine expects at steady
                   state; crossing it flips the unexpected_compile flag on
                   that step's trace event and bumps the sentinel counter
    timeline     : record per-request span timelines (queue / prefill
                   chunks / decode / instants) for Perfetto export
    lineage      : host-side page-lineage ledger over the first attention
                   layer (one extra jitted snapshot gather per step)
    regret_every : probe eviction regret on every Nth decode step of each
                   request (0 == off). NONZERO recompiles the step with
                   per-layer taps and transfers them every step — a
                   forensics mode, not a serving default.
    """
    metrics: bool = True
    trace_path: str | None = None
    profiler_annotations: bool = False
    program_ceiling: int = 2
    timeline: bool = False
    lineage: bool = False
    regret_every: int = 0

    @property
    def enabled(self) -> bool:
        return (self.metrics or self.trace_path is not None or self.timeline
                or self.lineage or self.regret_every > 0)


@dataclass
class EngineObs:
    """Live telemetry state owned by one Engine."""
    cfg: ObsConfig
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    writer: TraceWriter | None = None
    timeline: TimelineRecorder | None = None
    ledger: PageLineageLedger | None = None

    def __post_init__(self):
        if self.cfg.trace_path and self.writer is None:
            self.writer = TraceWriter(self.cfg.trace_path)
        if self.cfg.timeline and self.timeline is None:
            self.timeline = TimelineRecorder()
        if self.cfg.lineage and self.ledger is None:
            self.ledger = PageLineageLedger(layer=0)

    def close(self) -> None:
        if self.writer is not None:
            self.writer.close()
