"""Host-side page-lineage ledger: every page's life, every request's losses.

The engine snapshots ONE tracked attention layer once per step via
``core.paged_cache.lineage_snapshot`` (block table, ref counts, per-page
token counts / base positions / policy scores — one small jitted gather).
:meth:`PageLineageLedger.observe_step` diffs consecutive snapshots and,
using the step plan for context (which rows were reset, which adopted a
prefix from whom), classifies each block-table mutation into one of five
event types:

========  ==========================================================
alloc     a fresh physical page was mapped into (slot, lpi)
adopt     the mapping was copied from another row's prefix (CoW share)
fork      the row remapped (slot, lpi) to a private copy (CoW fork)
evict     the policy unmapped the page (carries the pre-step score)
release   the mapping was dropped because the row was reset/retired
========  ==========================================================

The same events are emitted as schema-v2 ``rec == "event"`` trace records,
so :meth:`PageLineageLedger.from_trace` can rebuild the ledger offline
from a trace file alone.

Contract (DESIGN.md §10, tested in tests/test_lineage.py): the ledger's
replayed block table equals the device block table after EVERY step, and
``ref_count`` equals the column count of the replayed table (the
mapping-count invariant) — :meth:`reconcile` returns the violations.
Within-step churn (a page allocated and evicted inside one step) is
invisible to snapshot diffs by design; count cross-checks against the
devstats vector are therefore inequalities, while *state* reconciliation
stays exact.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.obs.trace import TRACE_SCHEMA_VERSION


@dataclass
class PageEvent:
    """One mutation of the tracked layer's page pool (= one v2 trace
    ``event`` record)."""
    step: int
    etype: str          # alloc | adopt | fork | evict | release
    page: int           # physical page id
    slot: int           # owner row
    lpi: int            # logical page index within the row
    layer: int = 0
    src_page: int | None = None   # fork: page the copy split from
    src_slot: int | None = None   # adopt: row the prefix came from
    score: float | None = None    # evict: policy score priced pre-step
    tokens: int | None = None     # live tokens on the page at event time
    pos: int | None = None        # first token position on the page

    def to_record(self) -> dict:
        rec = {"v": TRACE_SCHEMA_VERSION, "rec": "event", "step": self.step,
               "etype": self.etype, "page": self.page, "slot": self.slot,
               "lpi": self.lpi, "layer": self.layer}
        for k in ("src_page", "src_slot", "score", "tokens", "pos"):
            val = getattr(self, k)
            if val is not None:
                rec[k] = val
        return rec

    @classmethod
    def from_record(cls, rec: dict) -> "PageEvent":
        return cls(step=rec["step"], etype=rec["etype"], page=rec["page"],
                   slot=rec["slot"], lpi=rec["lpi"],
                   layer=rec.get("layer", 0),
                   src_page=rec.get("src_page"),
                   src_slot=rec.get("src_slot"), score=rec.get("score"),
                   tokens=rec.get("tokens"), pos=rec.get("pos"))


def _np_snap(snap: dict) -> dict:
    return {k: np.asarray(v) for k, v in snap.items()}


@dataclass
class StepPlanContext:
    """The scheduler facts the diff needs to disambiguate event types."""
    reset_slots: frozenset = frozenset()
    # dst slot -> (src slot, n shared pages)
    adopt: dict = field(default_factory=dict)


class PageLineageLedger:
    """Diff-and-replay ledger over one tracked attention layer."""

    def __init__(self, layer: int = 0):
        self.layer = layer
        self.events: list[PageEvent] = []
        self._prev: dict | None = None
        self._bt: np.ndarray | None = None   # replayed block table
        self._pool_pages: int | None = None

    # ------------------------------------------------------------ ingest
    def observe_step(self, step: int, snap: dict,
                     plan: StepPlanContext | None = None) -> list[PageEvent]:
        """Diff the new snapshot against the previous one; returns (and
        retains) the events derived for this step."""
        snap = _np_snap(snap)
        plan = plan or StepPlanContext()
        new_events: list[PageEvent] = []
        cur_bt = snap["block_table"]
        if self._prev is None:
            # first observation: everything mapped is a pre-existing alloc
            B, P = cur_bt.shape
            for b in range(B):
                for p in range(P):
                    if cur_bt[b, p] >= 0:
                        new_events.append(self._ev(step, "alloc", snap, b, p))
        else:
            prev_bt = self._prev["block_table"]
            B, P = cur_bt.shape
            for b in range(B):
                in_reset = b in plan.reset_slots
                adopt = plan.adopt.get(b)
                for p in range(P):
                    g0, g1 = int(prev_bt[b, p]), int(cur_bt[b, p])
                    if g0 == g1:
                        if g0 < 0:
                            continue
                        if in_reset:
                            # reset rows release everything, so an unchanged
                            # mapping means the SAME physical page was
                            # recycled into the new occupant's row
                            new_events.append(
                                self._unmap_ev(step, b, p, g0, True))
                            new_events.append(
                                self._map_ev(step, snap, b, p, adopt))
                        elif (int(self._prev["tokens_per_page"][b, p]) > 0
                              and int(snap["tokens_per_page"][b, p]) == 0
                              and int(snap["cur_page"][b]) == p):
                            # policy eviction + working-page rollover that
                            # recycled the SAME physical page into the SAME
                            # slot — invisible to a block-table diff, visible
                            # as the slot becoming the row's EMPTY working
                            # page (the realloc'd page takes its first token
                            # next step)
                            new_events.append(
                                self._unmap_ev(step, b, p, g0, False))
                            new_events.append(
                                self._ev(step, "alloc", snap, b, p))
                        continue
                    if g0 >= 0 and g1 >= 0 and not in_reset:
                        # same-slot remap. A CoW fork carries the copied
                        # tokens; an evict + working-page rollover lands an
                        # EMPTY page (rollover is the step's last mutation,
                        # the first write comes next step).
                        if int(snap["tokens_per_page"][b, p]) > 0:
                            new_events.append(self._ev(step, "fork", snap,
                                                       b, p, src_page=g0))
                        else:
                            new_events.append(
                                self._unmap_ev(step, b, p, g0, False))
                            new_events.append(
                                self._ev(step, "alloc", snap, b, p))
                        continue
                    if g0 >= 0:
                        new_events.append(
                            self._unmap_ev(step, b, p, g0, in_reset))
                    if g1 >= 0:
                        new_events.append(
                            self._map_ev(step, snap, b, p, adopt))
        # replay onto ledger state
        if self._bt is None:
            self._bt = np.full_like(cur_bt, -1)
            self._pool_pages = int(snap["ref_count"].shape[0])
        for ev in new_events:
            if ev.etype in ("release", "evict"):
                if self._bt[ev.slot, ev.lpi] == ev.page:
                    self._bt[ev.slot, ev.lpi] = -1
            else:
                self._bt[ev.slot, ev.lpi] = ev.page
        self.events.extend(new_events)
        self._prev = snap
        return new_events

    def _ev(self, step, etype, snap, b, p, **kw) -> PageEvent:
        return PageEvent(
            step=step, etype=etype, page=int(snap["block_table"][b, p]),
            slot=b, lpi=p, layer=self.layer,
            tokens=int(snap["tokens_per_page"][b, p]),
            pos=int(snap["pos_base"][b, p]), **kw)

    def _unmap_ev(self, step, b, p, g0, in_reset) -> PageEvent:
        prev = self._prev
        if in_reset:
            return PageEvent(step=step, etype="release", page=g0, slot=b,
                             lpi=p, layer=self.layer,
                             tokens=int(prev["tokens_per_page"][b, p]),
                             pos=int(prev["pos_base"][b, p]))
        score = float(prev["page_scores"][b, p])
        return PageEvent(step=step, etype="evict", page=g0, slot=b, lpi=p,
                         layer=self.layer,
                         score=score if np.isfinite(score) else None,
                         tokens=int(prev["tokens_per_page"][b, p]),
                         pos=int(prev["pos_base"][b, p]))

    def _map_ev(self, step, snap, b, p, adopt) -> PageEvent:
        if adopt is not None:
            src, n_pages = adopt
            g1 = int(snap["block_table"][b, p])
            if p < n_pages and int(self._prev["block_table"][src, p]) == g1:
                return self._ev(step, "adopt", snap, b, p, src_slot=int(src))
        return self._ev(step, "alloc", snap, b, p)

    # --------------------------------------------------------- reconcile
    def replayed_block_table(self) -> np.ndarray | None:
        return None if self._bt is None else self._bt.copy()

    def replayed_ref_count(self) -> np.ndarray | None:
        """ref_count derived purely from the replayed block table: a page's
        refcount is the number of rows mapping it (the CoW invariant)."""
        if self._bt is None:
            return None
        mapped = self._bt[self._bt >= 0]
        return np.bincount(mapped, minlength=self._pool_pages).astype(np.int32)

    def reconcile(self, snap: dict) -> list:
        """Exact-state check against a device snapshot; returns mismatch
        descriptions (empty == the ledger and the device agree)."""
        snap = _np_snap(snap)
        errs = []
        if self._bt is None:
            return ["ledger has observed no steps"]
        bt = snap["block_table"]
        if not np.array_equal(self._bt, bt):
            bad = np.argwhere(self._bt != bt)
            for b, p in bad[:5]:
                errs.append(f"block_table[{b},{p}]: ledger "
                            f"{self._bt[b, p]} != device {bt[b, p]}")
            if len(bad) > 5:
                errs.append(f"... {len(bad) - 5} more block-table mismatches")
        rc = self.replayed_ref_count()
        dev_rc = snap["ref_count"]
        if not np.array_equal(rc, dev_rc):
            bad = np.argwhere(rc != dev_rc).ravel()
            for g in bad[:5]:
                errs.append(f"ref_count[{g}]: ledger {rc[g]} != device "
                            f"{dev_rc[g]}")
            if len(bad) > 5:
                errs.append(f"... {len(bad) - 5} more ref-count mismatches")
        return errs

    # ----------------------------------------------------------- queries
    def page_history(self, page: int) -> list:
        """Every event that touched physical page ``page``, in step order —
        the page's life across owners and reuses."""
        return [ev for ev in self.events if ev.page == page
                or ev.src_page == page]

    def request_loss_report(self, slot: int, *, since_step: int = 0) -> dict:
        """\"What did I lose\": the pages evicted out from under ``slot``
        (policy evictions only — resets/releases are lifecycle, not loss)."""
        losses = [ev for ev in self.events
                  if ev.etype == "evict" and ev.slot == slot
                  and ev.step >= since_step]
        scores = [ev.score for ev in losses if ev.score is not None]
        return {
            "slot": slot,
            "pages_lost": len(losses),
            "tokens_lost": sum(ev.tokens or 0 for ev in losses),
            "positions": [(ev.pos, (ev.pos or 0) + (ev.tokens or 0))
                          for ev in losses if ev.pos is not None
                          and ev.pos >= 0],
            "mean_evict_score": (float(np.mean(scores)) if scores else None),
            "events": losses,
        }

    def counts(self) -> dict:
        out: dict = {}
        for ev in self.events:
            out[ev.etype] = out.get(ev.etype, 0) + 1
        return out

    # ------------------------------------------------------ construction
    @classmethod
    def from_events(cls, events, *, batch: int, num_pages: int,
                    pool_pages: int, layer: int = 0) -> "PageLineageLedger":
        """Rebuild a ledger by replaying event records (e.g. parsed from a
        v2 trace file) — no snapshots needed."""
        led = cls(layer=layer)
        led._bt = np.full((batch, num_pages), -1, np.int32)
        led._pool_pages = pool_pages
        for ev in sorted(events, key=lambda e: e.step):
            if ev.etype in ("release", "evict"):
                if led._bt[ev.slot, ev.lpi] == ev.page:
                    led._bt[ev.slot, ev.lpi] = -1
            else:
                led._bt[ev.slot, ev.lpi] = ev.page
            led.events.append(ev)
        return led

    @classmethod
    def from_trace(cls, path: str, *, batch: int, num_pages: int,
                   pool_pages: int, layer: int = 0) -> "PageLineageLedger":
        import json
        events = []
        with open(path) as f:
            for line in f:
                rec = json.loads(line)
                if rec.get("rec") == "event" and rec.get("layer", 0) == layer:
                    events.append(PageEvent.from_record(rec))
        return cls.from_events(events, batch=batch, num_pages=num_pages,
                               pool_pages=pool_pages, layer=layer)
