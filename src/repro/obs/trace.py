"""Structured trace: buffered JSONL writer + versioned schema + profiler scopes.

Schema **v2** (this file) carries three record kinds on one stream,
discriminated by the required ``rec`` field:

- ``rec == "step"``  — one per ``Engine.step`` iteration (same shape as the
  v1 flat event, plus ``rec``).
- ``rec == "event"`` — one per page-lineage mutation (alloc / adopt / fork /
  evict / release) observed on the tracked attention layer, with the
  physical page id, owner slot, logical page index, and the policy score
  at eviction (``obs/lineage.py`` consumes these).
- ``rec == "probe"`` — one per sampled eviction-regret shadow probe
  (``obs/regret.py``): per-layer output divergence vs an uncompressed
  shadow cache and the attention mass attributable to evicted pages.

Records are flat JSON objects so any tool (jq, pandas,
``benchmarks/roofline.py --obs``) can consume them without a reader
library. :func:`validate_event` / :func:`validate_file` are the contract
and version-dispatch: **v1 files stay valid** (a v1 record has ``v == 1``
and no ``rec``; tests pin this on a checked-in fixture).

The writer buffers ``flush_every`` encoded lines before touching the file
so the hot path pays one json.dumps per record and an amortized write —
never an fsync. Crash safety: the writer registers an ``atexit`` fallback
at construction (unregistered on close) so an unhandled exception or
normal interpreter exit still lands the buffered tail; the engine loop
additionally flushes on error. SIGKILL can still lose at most
``flush_every - 1`` records — by design (no fsync on the hot path).

``annotation(name)`` wraps a host region in ``jax.profiler.TraceAnnotation``
when profiler annotations are enabled AND the jax build has them —
otherwise it is a zero-cost nullcontext, so the engine can always write
``with trace.annotation("engine.step"):`` unconditionally.
"""
from __future__ import annotations

import atexit
import contextlib
import json
from typing import IO

TRACE_SCHEMA_VERSION = 2

# ---------------------------------------------------------------------------
# schemas: field -> (type(s), required)
# ---------------------------------------------------------------------------

# v1 step event (PR 8). Integer counter fields are per-STEP deltas (device
# stats vector summed over layers), not running totals; *_ms are host
# wall-clock milliseconds. Kept verbatim for back-compat validation.
TRACE_SCHEMA_V1: dict = {
    "v": (int, True),               # schema version
    "step": (int, True),            # engine step counter at emission
                                    # (monotonic, 1-based after each step)
    "kind": (str, True),            # "decode" | "mixed" | "prefill" | "idle"
    "t_ms": (float, True),          # host time since engine start
    "plan_ms": (float, True),       # scheduler plan() wall time
    "step_ms": (float, True),       # jitted step wall time (dispatch+sync)
    "decode_rows": (int, True),     # batch mix this iteration
    "prefill_rows": (int, True),
    "reset_rows": (int, True),
    "adopt_rows": (int, True),
    "tokens": (int, True),          # live tokens consumed (sum n_tok)
    "tokens_written": (int, False),     # device stats (absent if obs off)
    "pages_allocated": (int, False),
    "pages_freed": (int, False),
    "pages_released": (int, False),
    "pages_adopted": (int, False),
    "pages_forked": (int, False),
    "pages_evicted": (int, False),
    "tokens_evicted": (int, False),
    "forced_evictions": (int, False),
    "pool_pages": (int, False),     # physical pool size (per layer)
    "free_pages": (int, False),     # engine's running free-list estimate
    "programs": (int, True),        # compiled-program cache size (sentinel)
    "unexpected_compile": (bool, False),  # step crossed the known ceiling
    "finished": (int, True),        # requests retired this step
}

# v2 step record: v1 shape + the "rec" discriminator.
TRACE_STEP_SCHEMA: dict = dict(TRACE_SCHEMA_V1, rec=(str, True))

# v2 page-lineage event record. One per mutation of the tracked attention
# layer's page pool, derived host-side (engine snapshot diff + step plan).
TRACE_EVENT_SCHEMA: dict = {
    "v": (int, True),
    "rec": (str, True),
    "step": (int, True),            # engine step the mutation landed on
    "etype": (str, True),           # alloc | adopt | fork | evict | release
    "page": (int, True),            # physical page id in the pool
    "slot": (int, True),            # owner batch slot (request row)
    "lpi": (int, True),             # logical page index within the row
    "layer": (int, False),          # tracked attention layer index
    "src_page": (int, False),       # fork: physical source page copied from
    "src_slot": (int, False),       # adopt: source row the prefix came from
    "score": (float, False),        # policy score at eviction (evict only)
    "tokens": (int, False),         # tokens live on the page at event time
    "pos": (int, False),            # first token position on the page
}

# v2 regret-probe record. One per sampled shadow probe (obs/regret.py):
# lists are per-transformer-layer, index 0 == first attention layer.
TRACE_PROBE_SCHEMA: dict = {
    "v": (int, True),
    "rec": (str, True),
    "step": (int, True),
    "slot": (int, True),            # probed batch slot
    "request_id": (str, False),
    "pos": (int, True),             # token position probed (row's last live)
    "divergence": (list, True),     # per-layer relative L2 of attn output
    "evicted_mass": (list, True),   # per-layer shadow attn mass on evicted
                                    # positions (0..1)
    "tokens_evicted": (int, False), # positions missing from the pruned row
}

# Back-compat alias: TRACE_SCHEMA has meant "the step-event schema" since
# PR 8; keep it pointing at the current step-record shape.
TRACE_SCHEMA = TRACE_STEP_SCHEMA

_V2_SCHEMAS = {
    "step": TRACE_STEP_SCHEMA,
    "event": TRACE_EVENT_SCHEMA,
    "probe": TRACE_PROBE_SCHEMA,
}
_STEP_KINDS = ("decode", "mixed", "prefill", "idle")
_EVENT_TYPES = ("alloc", "adopt", "fork", "evict", "release")


def _check_fields(ev: dict, schema: dict) -> list:
    errs = []
    for key, (typ, required) in schema.items():
        if key not in ev:
            if required:
                errs.append(f"missing required field {key!r}")
            continue
        val = ev[key]
        ok = isinstance(val, typ) and not (typ is int and isinstance(val, bool))
        if typ is float:
            ok = isinstance(val, (int, float)) and not isinstance(val, bool)
        if not ok:
            errs.append(f"{key!r}: expected {typ.__name__}, "
                        f"got {type(val).__name__}")
    for key in ev:
        if key not in schema:
            errs.append(f"unknown field {key!r}")
    return errs


def validate_event(ev: dict) -> list:
    """Return a list of schema violations (empty == valid).

    Version-dispatched: ``v == 1`` (or absent, for pre-versioned files)
    validates against the v1 step schema; ``v == 2`` dispatches on ``rec``.
    """
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not object"]
    v = ev.get("v", 1)
    if v == 1:
        errs = _check_fields(ev, TRACE_SCHEMA_V1)
        if ev.get("kind") not in (None,) + _STEP_KINDS:
            errs.append(f"bad kind {ev.get('kind')!r}")
        return errs
    if v != TRACE_SCHEMA_VERSION:
        return [f"schema version {v!r} not in (1, {TRACE_SCHEMA_VERSION})"]
    rec = ev.get("rec")
    schema = _V2_SCHEMAS.get(rec)
    if schema is None:
        return [f"bad rec {rec!r} (want one of {sorted(_V2_SCHEMAS)})"]
    errs = _check_fields(ev, schema)
    if rec == "step" and ev.get("kind") not in (None,) + _STEP_KINDS:
        errs.append(f"bad kind {ev.get('kind')!r}")
    if rec == "event" and ev.get("etype") not in (None,) + _EVENT_TYPES:
        errs.append(f"bad etype {ev.get('etype')!r}")
    return errs


def validate_file(path: str, max_errors: int = 20) -> list:
    """Validate every line of a JSONL trace (v1 or v2); returns violations
    with line numbers (empty == valid file)."""
    errs = []
    with open(path) as f:
        n = -1
        for n, line in enumerate(f):
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"line {n}: not JSON ({e})")
                continue
            for e in validate_event(ev):
                errs.append(f"line {n}: {e}")
            if len(errs) >= max_errors:
                errs.append("... (truncated)")
                return errs
        if n < 0:
            errs.append("empty trace")
    return errs


class TraceWriter:
    """Buffered JSONL sink. ``emit`` encodes and appends to an in-memory
    list; the file is written every ``flush_every`` events and on close.

    An ``atexit`` hook (installed at construction, removed on close) flushes
    the tail if the process exits — cleanly or via unhandled exception —
    without the owner calling ``close()``. Idempotent: double-close and
    close-after-atexit are no-ops."""

    def __init__(self, path: str, flush_every: int = 64):
        self.path = path
        self.flush_every = max(1, flush_every)
        self.events_written = 0
        self._buf: list = []
        self._f: IO | None = open(path, "w")
        atexit.register(self.close)

    def emit(self, ev: dict) -> None:
        if self._f is None:
            raise ValueError(f"TraceWriter({self.path}) is closed")
        self._buf.append(json.dumps(ev, separators=(",", ":")))
        self.events_written += 1
        if len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self._buf and self._f is not None:
            self._f.write("\n".join(self._buf) + "\n")
            self._f.flush()
            self._buf.clear()

    def close(self) -> None:
        if self._f is not None:
            self.flush()
            self._f.close()
            self._f = None
            with contextlib.suppress(Exception):
                atexit.unregister(self.close)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def annotation(name: str, enabled: bool = True):
    """Context manager: ``jax.profiler.TraceAnnotation(name)`` when enabled
    and available, else a nullcontext. Lets device profiles line up with
    host-side trace events without making jax.profiler a hard dependency."""
    if not enabled:
        return contextlib.nullcontext()
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except (ImportError, AttributeError):
        return contextlib.nullcontext()


def main(argv=None) -> int:
    """CLI: ``python -m repro.obs.trace TRACE.jsonl`` — exit 0 iff valid."""
    import argparse
    ap = argparse.ArgumentParser(description="validate a trace JSONL file")
    ap.add_argument("path")
    args = ap.parse_args(argv)
    errs = validate_file(args.path)
    if errs:
        for e in errs:
            print(f"INVALID {args.path}: {e}")
        return 1
    counts: dict = {}
    with open(args.path) as f:
        for line in f:
            ev = json.loads(line)
            key = f"v{ev.get('v', 1)}:{ev.get('rec', 'step')}"
            counts[key] = counts.get(key, 0) + 1
    total = sum(counts.values())
    mix = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"OK {args.path}: {total} records ({mix})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
