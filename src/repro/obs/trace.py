"""Structured per-step trace: buffered JSONL writer + schema + profiler scopes.

One event per ``Engine.step`` iteration. Events are flat JSON objects so
any tool (jq, pandas, ``benchmarks/roofline.py --obs``) can consume them
without a reader library; the schema below is the contract and
:func:`validate_event` enforces it (tests + the CI trace step call it).

The writer buffers ``flush_every`` encoded lines before touching the file
so the hot path pays one json.dumps per step and an amortized write —
never an fsync. Use as a context manager or call close(); atexit is NOT
installed (serving drivers own their shutdown order).

``annotation(name)`` wraps a host region in ``jax.profiler.TraceAnnotation``
when profiler annotations are enabled AND the jax build has them —
otherwise it is a zero-cost nullcontext, so the engine can always write
``with trace.annotation("engine.step"):`` unconditionally.
"""
from __future__ import annotations

import contextlib
import json
from typing import IO

# Trace event schema, version 1. field -> (type(s), required).
# Integer counter fields are per-STEP deltas (device stats vector summed
# over layers), not running totals; *_ms are host wall-clock milliseconds.
TRACE_SCHEMA_VERSION = 1
TRACE_SCHEMA: dict = {
    "v": (int, True),               # schema version
    "step": (int, True),            # engine step counter at emission
                                    # (monotonic, 1-based after each step)
    "kind": (str, True),            # "decode" | "mixed" | "prefill" | "idle"
    "t_ms": (float, True),          # host time since engine start
    "plan_ms": (float, True),       # scheduler plan() wall time
    "step_ms": (float, True),       # jitted step wall time (dispatch+sync)
    "decode_rows": (int, True),     # batch mix this iteration
    "prefill_rows": (int, True),
    "reset_rows": (int, True),
    "adopt_rows": (int, True),
    "tokens": (int, True),          # live tokens consumed (sum n_tok)
    "tokens_written": (int, False),     # device stats (absent if obs off)
    "pages_allocated": (int, False),
    "pages_freed": (int, False),
    "pages_released": (int, False),
    "pages_adopted": (int, False),
    "pages_forked": (int, False),
    "pages_evicted": (int, False),
    "tokens_evicted": (int, False),
    "forced_evictions": (int, False),
    "pool_pages": (int, False),     # physical pool size (per layer)
    "free_pages": (int, False),     # engine's running free-list estimate
    "programs": (int, True),        # compiled-program cache size (sentinel)
    "unexpected_compile": (bool, False),  # step crossed the known ceiling
    "finished": (int, True),        # requests retired this step
}


def validate_event(ev: dict) -> list:
    """Return a list of schema violations (empty == valid)."""
    errs = []
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not object"]
    for key, (typ, required) in TRACE_SCHEMA.items():
        if key not in ev:
            if required:
                errs.append(f"missing required field {key!r}")
            continue
        val = ev[key]
        ok = isinstance(val, typ) and not (typ is int and isinstance(val, bool))
        if typ is float:
            ok = isinstance(val, (int, float)) and not isinstance(val, bool)
        if not ok:
            errs.append(f"{key!r}: expected {typ.__name__}, "
                        f"got {type(val).__name__}")
    for key in ev:
        if key not in TRACE_SCHEMA:
            errs.append(f"unknown field {key!r}")
    if ev.get("v") not in (None, TRACE_SCHEMA_VERSION):
        errs.append(f"schema version {ev.get('v')} != {TRACE_SCHEMA_VERSION}")
    if ev.get("kind") not in (None, "decode", "mixed", "prefill", "idle"):
        errs.append(f"bad kind {ev.get('kind')!r}")
    return errs


def validate_file(path: str, max_errors: int = 20) -> list:
    """Validate every line of a JSONL trace; returns violations with line
    numbers (empty == valid file)."""
    errs = []
    with open(path) as f:
        n = -1
        for n, line in enumerate(f):
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"line {n}: not JSON ({e})")
                continue
            for e in validate_event(ev):
                errs.append(f"line {n}: {e}")
            if len(errs) >= max_errors:
                errs.append("... (truncated)")
                return errs
        if n < 0:
            errs.append("empty trace")
    return errs


class TraceWriter:
    """Buffered JSONL sink. ``emit`` encodes and appends to an in-memory
    list; the file is written every ``flush_every`` events and on close."""

    def __init__(self, path: str, flush_every: int = 64):
        self.path = path
        self.flush_every = max(1, flush_every)
        self.events_written = 0
        self._buf: list = []
        self._f: IO | None = open(path, "w")

    def emit(self, ev: dict) -> None:
        if self._f is None:
            raise ValueError(f"TraceWriter({self.path}) is closed")
        self._buf.append(json.dumps(ev, separators=(",", ":")))
        self.events_written += 1
        if len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self._buf and self._f is not None:
            self._f.write("\n".join(self._buf) + "\n")
            self._buf.clear()

    def close(self) -> None:
        if self._f is not None:
            self.flush()
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def annotation(name: str, enabled: bool = True):
    """Context manager: ``jax.profiler.TraceAnnotation(name)`` when enabled
    and available, else a nullcontext. Lets device profiles line up with
    host-side trace events without making jax.profiler a hard dependency."""
    if not enabled:
        return contextlib.nullcontext()
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except (ImportError, AttributeError):
        return contextlib.nullcontext()


def main(argv=None) -> int:
    """CLI: ``python -m repro.obs.trace TRACE.jsonl`` — exit 0 iff valid."""
    import argparse
    ap = argparse.ArgumentParser(description="validate a trace JSONL file")
    ap.add_argument("path")
    args = ap.parse_args(argv)
    errs = validate_file(args.path)
    if errs:
        for e in errs:
            print(f"INVALID {args.path}: {e}")
        return 1
    with open(args.path) as f:
        n = sum(1 for _ in f)
    print(f"OK {args.path}: {n} events, schema v{TRACE_SCHEMA_VERSION}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
