"""Host-side metrics: counters, gauges, fixed-bucket latency histograms.

Design constraints (DESIGN.md §9): every instrument is a few Python floats
— ``observe()`` on the serving hot path is O(log n_buckets) with zero
allocation, so the registry itself can never be the overhead the
BENCH_obs gate measures. Histograms use FIXED log-spaced bucket bounds
(~100 us .. ~60 s, 8 per decade) chosen once at import: snapshots from
different runs/processes are mergeable bucket-by-bucket, and quantiles
come from linear interpolation inside the bucket (error bounded by the
~33% bucket width — tests/test_obs.py pins this against numpy on random
latency draws).

Metric names are dot-paths (``engine.step.wall_s``); units live in the
name suffix (``_s`` seconds, ``_ms`` never — everything is seconds) so a
snapshot is self-describing. The registry is snapshot-able to a plain
dict (JSON-safe) and renderable as a text dashboard (launch/serve.py).
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Iterable


def _default_bounds() -> tuple:
    """Log-spaced upper bounds, 8 per decade over [1e-4, 60] seconds."""
    bounds = []
    lo, hi = -4.0, math.log10(60.0)
    n = int(round((hi - lo) * 8))
    for i in range(n + 1):
        bounds.append(10.0 ** (lo + (hi - lo) * i / n))
    return tuple(bounds)


LATENCY_BOUNDS_S = _default_bounds()


class Counter:
    """Monotonic non-negative accumulator."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self.value += n

    def snapshot(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def snapshot(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max and interpolated
    quantiles. ``bounds`` are inclusive upper edges; one overflow bucket
    catches everything above the last bound."""

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, bounds: Iterable[float] = LATENCY_BOUNDS_S):
        self.name = name
        self.bounds = tuple(bounds)
        assert list(self.bounds) == sorted(self.bounds), name
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (0 <= q <= 1); nan when empty. Exact
        min/max clamp the first/last occupied buckets, so q=0 and q=1 are
        exact and interior quantiles never leave the observed range."""
        if self.count == 0:
            return math.nan
        rank = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else 0.0
            hi = self.bounds[i] if i < len(self.bounds) else self.max
            lo, hi = max(lo, self.min), min(max(hi, lo), self.max)
            if seen + c >= rank:
                frac = min(max((rank - seen) / c, 0.0), 1.0)
                return lo + (hi - lo) * frac
            seen += c
        return self.max

    def snapshot(self):
        d = {"type": "histogram", "count": self.count, "sum": self.sum,
             "min": self.min if self.count else None,
             "max": self.max if self.count else None,
             "mean": (self.sum / self.count) if self.count else None,
             "buckets": {f"{b:.6g}": c
                         for b, c in zip(self.bounds, self.counts) if c},
             "overflow": self.counts[-1]}
        for q, tag in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
            v = self.quantile(q)
            d[tag] = None if math.isnan(v) else v
        return d


class MetricsRegistry:
    """Name -> instrument map. get-or-create accessors keep call sites
    one-liners; a name can only ever hold one instrument type."""

    def __init__(self):
        self._m: dict = {}

    def _get(self, name: str, cls, *args):
        inst = self._m.get(name)
        if inst is None:
            inst = self._m[name] = cls(name, *args)
        elif not isinstance(inst, cls):
            raise TypeError(f"{name} is {type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, bounds=LATENCY_BOUNDS_S) -> Histogram:
        return self._get(name, Histogram, bounds)

    def names(self):
        return sorted(self._m)

    def snapshot(self) -> dict:
        """JSON-safe dict of every instrument."""
        return {name: self._m[name].snapshot() for name in self.names()}

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
            f.write("\n")

    # ------------------------------------------------------------- dashboard
    def render(self) -> str:
        """Text dashboard: counters/gauges as a two-column table, histograms
        as count/mean/p50/p90/p99/max rows (seconds shown in ms)."""
        lines = []
        scalars = [(n, i) for n, i in sorted(self._m.items())
                   if isinstance(i, (Counter, Gauge))]
        hists = [(n, i) for n, i in sorted(self._m.items())
                 if isinstance(i, Histogram)]
        if scalars:
            w = max(len(n) for n, _ in scalars)
            lines.append("-- counters / gauges " + "-" * max(1, w - 9))
            for n, inst in scalars:
                v = inst.value
                sv = f"{v:.4g}" if isinstance(v, float) else str(v)
                lines.append(f"  {n:<{w}}  {sv:>12}")
        if hists:
            w = max(len(n) for n, _ in hists)
            lines.append("-- latency histograms (ms) " + "-" * max(1, w - 15))
            hdr = f"  {'name':<{w}}  {'count':>7} {'mean':>9} {'p50':>9} " \
                  f"{'p90':>9} {'p99':>9} {'max':>9}"
            lines.append(hdr)
            for n, h in hists:
                if h.count == 0:
                    lines.append(f"  {n:<{w}}  {0:>7}")
                    continue
                ms = lambda x: f"{x * 1e3:>9.2f}"
                lines.append(
                    f"  {n:<{w}}  {h.count:>7} {ms(h.sum / h.count)} "
                    f"{ms(h.quantile(.5))} {ms(h.quantile(.9))} "
                    f"{ms(h.quantile(.99))} {ms(h.max)}")
        return "\n".join(lines)
