"""Eviction-regret shadow probes: what did pruning cost THIS request?

The paper's claim is accuracy-vs-memory; throughput telemetry (PR 8) can't
see quality. This module measures eviction's counterfactual cost online:
the engine keeps an **uncompressed shadow copy** of every attention
layer's K/V history (host RAM, never HBM), fed by per-step taps out of the
jitted step — the SAME k/v/q the pruned path computed, so the shadow holds
the production activations, not a re-run. Every ``every_n``-th decode step
of a probed request, :func:`run_probe` recomputes full-cache attention
against the shadow history and records, per layer:

- ``divergence`` — relative L2 between the pruned attention output and the
  full-cache shadow output at the row's probed token;
- ``evicted_mass`` — the shadow softmax mass landing on positions the
  pruned cache no longer holds (attention the policy threw away).

A ``full``-policy engine probes to ~zero on both (the shadow recompute is
the same math in f32), while ``paged_eviction`` under budget pressure
shows nonzero regret — tests and the ``--smoke`` CLI gate exactly that.
Probes off (``ObsConfig.regret_every == 0``) is python-static: the engine
compiles the identical program and produces bit-identical outputs.

Probe cost is per-step tap transfer (k/v/q/o for every attention layer)
plus numpy attention on sampled steps — a forensics mode, not a serving
default; the CI smoke step documents the measured overhead.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.obs.trace import TRACE_SCHEMA_VERSION

# eviction_regret histogram bounds: divergence/evicted-mass live in [0, ~1];
# log-spaced so "~zero" (full cache, float noise) and "real" (pruned) regret
# land decades apart.
REGRET_BOUNDS = tuple(float(b) for b in np.geomspace(1e-6, 1.0, 25))


@dataclass
class RegretConfig:
    """Sampling knobs for the shadow probes."""
    every_n: int = 8          # probe every Nth decode step of a probed row
    max_probes: int = 0       # stop probing a request after this many
                              # samples (0 == unlimited)


class ShadowState:
    """Uncompressed per-layer K/V history for every batch row (host numpy).

    Mirrors the pruned pool's lifecycle: rows are cleared on reset and
    prefix adoption copies the source row's history — so the shadow is
    exactly "the cache nothing was ever evicted from"."""

    def __init__(self, num_layers: int, batch: int, max_len: int,
                 kv_heads: int, head_dim: int):
        shp = (num_layers, batch, max_len, kv_heads, head_dim)
        self.k = np.zeros(shp, np.float32)
        self.v = np.zeros(shp, np.float32)
        self.written = np.zeros((batch, max_len), bool)
        self.max_len = max_len

    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes + self.written.nbytes

    def reset_row(self, b: int) -> None:
        self.written[b] = False

    def adopt(self, dst: int, src: int, n_tokens: int) -> None:
        n = min(n_tokens, self.max_len)
        self.k[:, dst, :n] = self.k[:, src, :n]
        self.v[:, dst, :n] = self.v[:, src, :n]
        self.written[dst, :n] = self.written[src, :n]

    def record_step(self, layers: list, positions: np.ndarray,
                    n_tok: np.ndarray) -> None:
        """Append this step's tapped K/V. ``layers``: per-attention-layer
        dicts with ``k``/``v`` (B, T, KV, hd); positions (B, T) int32 with
        -1 padding; n_tok (B,)."""
        B = positions.shape[0]
        for b in range(B):
            n = int(n_tok[b])
            if n == 0:
                continue
            idx = positions[b, :n].astype(np.int64)
            ok = (idx >= 0) & (idx < self.max_len)
            if not ok.any():
                continue
            idx = idx[ok]
            for li, tp in enumerate(layers):
                self.k[li, b, idx] = np.asarray(tp["k"][b, :n][ok],
                                                np.float32)
                self.v[li, b, idx] = np.asarray(tp["v"][b, :n][ok],
                                                np.float32)
            self.written[b, idx] = True


def _full_attention(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                    mask: np.ndarray):
    """GQA attention of one query against the shadow history (f32 numpy —
    same math as ``attention.paged_attention_ref``). q: (H, hd); k/v:
    (S, KV, hd); mask: (S,) valid. Returns (o (H, hd), probs (KV, G, S))."""
    H, hd = q.shape
    S, KV = k.shape[0], k.shape[1]
    G = H // KV
    qg = q.reshape(KV, G, hd).astype(np.float32)
    s = np.einsum("kgd,skd->kgs", qg, k.astype(np.float32)) / np.sqrt(hd)
    s = np.where(mask[None, None, :], s, -np.inf)
    s = s - s.max(axis=-1, keepdims=True)
    p = np.exp(s)
    denom = p.sum(axis=-1, keepdims=True)
    p = np.where(denom > 0, p / np.maximum(denom, 1e-30), 0.0)
    o = np.einsum("kgs,skd->kgd", p, v.astype(np.float32))
    return o.reshape(H, hd), p


def run_probe(shadow: ShadowState, layers: list, positions: np.ndarray,
              n_tok: np.ndarray, rows: list) -> list:
    """Shadow-probe the given batch rows at their last live token of this
    step. ``layers``: per-attention-layer taps with ``q``/``o`` (B, T, H,
    hd) and ``live_pos`` (B, P, page) — the pruned cache's positions AT
    ATTENTION TIME. Returns one dict per row: per-layer ``divergence`` and
    ``evicted_mass`` plus the evicted-position count."""
    out = []
    for b in rows:
        n = int(n_tok[b])
        if n == 0:
            continue
        t = n - 1
        qp = int(positions[b, t])
        if qp < 0 or qp >= shadow.max_len:
            continue
        hist = shadow.written[b, :qp + 1]
        divs, masses = [], []
        n_evicted = 0
        for li, tp in enumerate(layers):
            live = np.asarray(tp["live_pos"][b]).ravel()
            live = live[(live >= 0) & (live <= qp)]
            live_mask = np.zeros(qp + 1, bool)
            live_mask[live] = True
            evicted = hist & ~live_mask
            n_evicted = max(n_evicted, int(evicted.sum()))
            o_shadow, probs = _full_attention(
                np.asarray(tp["q"][b, t], np.float32),
                shadow.k[li, b, :qp + 1], shadow.v[li, b, :qp + 1], hist)
            o_pruned = np.asarray(tp["o"][b, t], np.float32)
            num = float(np.linalg.norm(o_shadow - o_pruned))
            den = float(np.linalg.norm(o_shadow)) + 1e-9
            divs.append(num / den)
            masses.append(float(probs[..., evicted].sum(axis=-1).mean()))
        out.append({"slot": int(b), "pos": qp, "divergence": divs,
                    "evicted_mass": masses, "tokens_evicted": n_evicted})
    return out


def probe_record(sample: dict, *, step: int, request_id=None) -> dict:
    """Format one run_probe sample as a schema-v2 ``probe`` trace record."""
    rec = {"v": TRACE_SCHEMA_VERSION, "rec": "probe", "step": step,
           "slot": sample["slot"], "pos": sample["pos"],
           "divergence": [round(float(d), 8) for d in sample["divergence"]],
           "evicted_mass": [round(float(m), 8)
                            for m in sample["evicted_mass"]],
           "tokens_evicted": sample["tokens_evicted"]}
    if request_id is not None:
        rec["request_id"] = str(request_id)
    return rec


def summarize_request(samples: list) -> dict | None:
    """Per-request regret summary over its probe samples (feeds
    ``benchmarks/accuracy.py`` and the serve dashboard)."""
    if not samples:
        return None
    div = np.array([np.mean(s["divergence"]) for s in samples])
    mass = np.array([np.mean(s["evicted_mass"]) for s in samples])
    return {
        "probes": len(samples),
        "mean_divergence": float(div.mean()),
        "max_divergence": float(div.max()),
        "mean_evicted_mass": float(mass.mean()),
        "max_evicted_mass": float(mass.max()),
        "tokens_evicted_last": int(samples[-1]["tokens_evicted"]),
    }


# ---------------------------------------------------------------------------
# smoke harness (CI: regret-probe gate; benchmarks/accuracy.py --regret)
# ---------------------------------------------------------------------------

def regret_smoke(policy: str = "paged_eviction", *, budget: int = 32,
                 page: int = 8, num_requests: int = 3, prompt_len: int = 48,
                 new_tokens: int = 24, every_n: int = 4, seed: int = 0,
                 arch: str = "llama-3.2-1b") -> dict:
    """Run a tiny engine with shadow probes on and summarize the regret.
    Pure-host harness used by the CI smoke step, tests, and
    ``benchmarks/accuracy.py --regret``."""
    import jax
    from repro.configs import ARCHS, CacheConfig
    from repro.models import init_model
    from repro.obs import ObsConfig
    from repro.serving import Engine, SamplingParams

    cfg = ARCHS[arch].reduced()
    params = init_model(jax.random.PRNGKey(seed), cfg)
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=num_requests,
                 max_prompt_len=prompt_len, max_new_tokens=new_tokens,
                 sampling=SamplingParams(greedy=True), seed=seed,
                 obs=ObsConfig(regret_every=every_n))
    rng = np.random.default_rng(seed)
    for _ in range(num_requests):
        eng.submit(rng.integers(0, cfg.vocab_size,
                                size=prompt_len).astype(np.int32))
    finished = eng.run()
    samples = [s for r in finished for s in r.regret_samples]
    summaries = [summarize_request(r.regret_samples) for r in finished]
    summaries = [s for s in summaries if s]
    agg = {
        "policy": policy, "budget": budget, "probes": len(samples),
        "mean_divergence": (float(np.mean([s["mean_divergence"]
                                           for s in summaries]))
                            if summaries else 0.0),
        "mean_evicted_mass": (float(np.mean([s["mean_evicted_mass"]
                                             for s in summaries]))
                              if summaries else 0.0),
        "shadow_mb": round(eng.shadow_nbytes() / 1e6, 3),
        "outputs": [list(r.output_tokens) for r in finished],
    }
    eng.close()
    return agg


def main(argv=None) -> int:
    """CLI: ``python -m repro.obs.regret --smoke`` — the CI gate.

    Asserts the acceptance criterion: nonzero eviction_regret for
    ``paged_eviction`` under budget pressure, ~zero for ``full``, and
    probes-off outputs identical to the never-instrumented engine."""
    import argparse
    import json
    ap = argparse.ArgumentParser(description="eviction-regret smoke gate")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, help="write summaries here")
    args = ap.parse_args(argv)
    del args.smoke  # only mode there is
    pruned = regret_smoke("paged_eviction")
    full = regret_smoke("full")
    ok = True
    if not (pruned["probes"] > 0 and pruned["mean_evicted_mass"] > 1e-4
            and pruned["mean_divergence"] > 1e-5):
        print(f"FAIL paged_eviction regret not visible: {pruned}")
        ok = False
    if not (full["probes"] > 0 and full["mean_divergence"] < 1e-3
            and full["mean_evicted_mass"] < 1e-6):
        print(f"FAIL full-cache regret not ~zero: {full}")
        ok = False
    for s in (pruned, full):
        s.pop("outputs")
        print("regret," + ",".join(f"{k}={v}" for k, v in s.items()))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"paged_eviction": pruned, "full": full}, f, indent=2)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
