"""Per-request span timelines → Chrome-trace / Perfetto JSON.

The engine and scheduler call the ``TimelineRecorder`` hooks with host
wall-clock times (``time.perf_counter()`` seconds); the recorder keeps
everything as plain python records and only does formatting work at
:meth:`export`. The export is the Chrome Trace Event Format (the JSON
flavour ``chrome://tracing`` and https://ui.perfetto.dev load directly):

- pid 1 / "engine": one ``X`` (complete) span per ``Engine.step`` with the
  batch-mix kind, plus ``i`` (instant) marks for page evictions.
- pid 2 / "requests": one tid per request, named after the request id,
  carrying the request's life as stacked spans — ``queue`` (submit →
  admission), ``prefill[k]`` for each prompt chunk, ``decode`` (first
  decode step → finish) — plus instants for prefix adoption and for
  evictions that hit the request's own pages (lineage-attributed when the
  ledger is on).

All spans carry ``args`` with the raw numbers (tokens, pages, scores) so
the Perfetto query engine can aggregate them. The recorder is pure host
bookkeeping — nothing here touches jax.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


def _us(t: float) -> float:
    return t * 1e6


@dataclass
class _ReqTrack:
    tid: int
    rid: str
    submit_t: float | None = None
    admit_t: float | None = None
    decode_t0: float | None = None
    decode_steps: int = 0
    chunks: list = field(default_factory=list)   # (t0, t1, tokens, index)
    instants: list = field(default_factory=list)  # (t, name, args)
    finish_t: float | None = None
    finish_args: dict = field(default_factory=dict)


class TimelineRecorder:
    """Assembles engine/scheduler hook calls into a Chrome-trace timeline."""

    def __init__(self):
        self._t0: float | None = None
        self._reqs: dict = {}        # rid -> _ReqTrack
        self._steps: list = []       # (t0, dur, step, kind, args)
        self._engine_instants: list = []  # (t, name, args)

    # -- clock ----------------------------------------------------------
    def _rel(self, t: float) -> float:
        if self._t0 is None:
            self._t0 = t
        return t - self._t0

    def _track(self, rid) -> _ReqTrack:
        rid = str(rid)
        if rid not in self._reqs:
            self._reqs[rid] = _ReqTrack(tid=len(self._reqs) + 1, rid=rid)
        return self._reqs[rid]

    # -- request hooks --------------------------------------------------
    def request_submitted(self, rid, t: float) -> None:
        self._track(rid).submit_t = self._rel(t)

    def request_admitted(self, rid, t: float, *, slot: int,
                         shared_tokens: int = 0, shared_pages: int = 0,
                         prompt_tokens: int = 0) -> None:
        tr = self._track(rid)
        tr.admit_t = self._rel(t)
        if shared_tokens:
            tr.instants.append((tr.admit_t, "adopt_prefix",
                                {"slot": slot, "shared_tokens": shared_tokens,
                                 "shared_pages": shared_pages}))
        tr.finish_args.update(slot=slot, prompt_tokens=prompt_tokens)

    def prefill_chunk(self, rid, t0: float, t1: float, *, tokens: int,
                      step: int) -> None:
        tr = self._track(rid)
        tr.chunks.append((self._rel(t0), self._rel(t1), tokens, step))

    def decode_step(self, rid, t0: float) -> None:
        """First call opens the request's decode span; later calls count."""
        tr = self._track(rid)
        if tr.decode_t0 is None:
            tr.decode_t0 = self._rel(t0)
        tr.decode_steps += 1

    def request_evicted_page(self, rid, t: float, *, page: int, lpi: int,
                             score: float | None = None) -> None:
        args = {"page": page, "lpi": lpi}
        if score is not None:
            args["score"] = score
        self._track(rid).instants.append((self._rel(t), "evict_page", args))

    def request_finished(self, rid, t: float, *, tokens: int = 0,
                         reason: str = "complete") -> None:
        tr = self._track(rid)
        tr.finish_t = self._rel(t)
        tr.finish_args.update(new_tokens=tokens, reason=reason)

    # -- engine hooks ---------------------------------------------------
    def engine_step(self, step: int, kind: str, t0: float, dur_s: float,
                    **args) -> None:
        self._steps.append((self._rel(t0), dur_s, step, kind, args))

    def engine_instant(self, t: float, name: str, **args) -> None:
        self._engine_instants.append((self._rel(t), name, args))

    # -- export ---------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        ev: list = [
            {"ph": "M", "pid": 1, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 1, "tid": 1, "name": "thread_name",
             "args": {"name": "step"}},
            {"ph": "M", "pid": 2, "name": "process_name",
             "args": {"name": "requests"}},
        ]
        for t0, dur, step, kind, args in self._steps:
            ev.append({"ph": "X", "pid": 1, "tid": 1, "ts": _us(t0),
                       "dur": _us(dur), "name": f"step:{kind}",
                       "cat": "engine", "args": dict(args, step=step)})
        for t, name, args in self._engine_instants:
            ev.append({"ph": "i", "pid": 1, "tid": 1, "ts": _us(t), "s": "t",
                       "name": name, "cat": "engine", "args": args})
        for tr in self._reqs.values():
            ev.append({"ph": "M", "pid": 2, "tid": tr.tid,
                       "name": "thread_name",
                       "args": {"name": f"req {tr.rid}"}})
            end = tr.finish_t
            if tr.submit_t is not None and tr.admit_t is not None:
                ev.append({"ph": "X", "pid": 2, "tid": tr.tid,
                           "ts": _us(tr.submit_t),
                           "dur": _us(max(tr.admit_t - tr.submit_t, 0.0)),
                           "name": "queue", "cat": "request", "args": {}})
            for i, (t0, t1, tokens, step) in enumerate(tr.chunks):
                ev.append({"ph": "X", "pid": 2, "tid": tr.tid,
                           "ts": _us(t0), "dur": _us(max(t1 - t0, 0.0)),
                           "name": f"prefill[{i}]", "cat": "request",
                           "args": {"tokens": tokens, "step": step}})
            if tr.decode_t0 is not None:
                d_end = end if end is not None else tr.decode_t0
                ev.append({"ph": "X", "pid": 2, "tid": tr.tid,
                           "ts": _us(tr.decode_t0),
                           "dur": _us(max(d_end - tr.decode_t0, 0.0)),
                           "name": "decode", "cat": "request",
                           "args": dict(tr.finish_args,
                                        decode_steps=tr.decode_steps)})
            for t, name, args in tr.instants:
                ev.append({"ph": "i", "pid": 2, "tid": tr.tid, "ts": _us(t),
                           "s": "t", "name": name, "cat": "request",
                           "args": args})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Perfetto/Chrome JSON; returns the event count."""
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f, separators=(",", ":"))
            f.write("\n")
        return len(doc["traceEvents"])


def validate_chrome_trace(doc: dict) -> list:
    """Structural validation of a Chrome-trace document (what
    ``chrome://tracing`` needs to load it). Returns violations."""
    errs = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents container"]
    if not isinstance(doc["traceEvents"], list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E"):
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev:
            errs.append(f"event {i}: missing name/pid")
        if ph == "X" and not (isinstance(ev.get("ts"), (int, float))
                              and isinstance(ev.get("dur"), (int, float))
                              and ev["dur"] >= 0):
            errs.append(f"event {i}: X needs numeric ts/dur>=0")
        if ph == "i" and ("ts" not in ev or ev.get("s") not in ("t", "p",
                                                                "g")):
            errs.append(f"event {i}: i needs ts and scope")
        if len(errs) >= 20:
            errs.append("... (truncated)")
            break
    return errs
