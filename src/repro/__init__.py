"""PagedEviction on TPU: paged KV caching with structured block-wise
eviction (Chitty-Venkata et al., 2025) as a production JAX framework."""
__version__ = "1.0.0"
