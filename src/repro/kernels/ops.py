"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the body
executes in Python via the Pallas interpreter); on a real TPU set
``repro.kernels.ops.INTERPRET = False`` (or env REPRO_PALLAS_COMPILE=1) and
the same ``pl.pallas_call`` lowers to Mosaic.

The paged-attention wrappers only permute the page POOL into the kernel's
(KV, N_pool, page, hd) tile layout — the per-request view is never
materialized; indirection happens inside the kernel through the
scalar-prefetched block table (DESIGN.md §2).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.core.importance import page_scores_from_norms
from repro.core.paged_cache import PagedLayerCache
from repro.kernels.block_score import block_score_kernel
from repro.kernels.flash_prefill import (
    flash_attention_kernel,
    paged_flash_prefill_kernel,
)
from repro.kernels.paged_attention import paged_attention_kernel

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _pool_layout(arr):
    """(N, page, KV, hd) -> (KV, N, page, hd) contiguous page tiles."""
    return jnp.moveaxis(arr, 2, 0)


def _epilogue_scores(cache: PagedLayerCache, norms, tp_axis=None):
    """(kn, vn) epilogue outputs (B, KV, P, page) -> Alg.1 page scores
    (B, P); identical to the standalone block_score pass (the oracle).
    Under TP the kernel only saw the LOCAL KV heads; ``tp_axis`` pmeans
    the head means across the mesh so every shard scores globally."""
    kn, vn = norms
    return page_scores_from_norms(kn, vn, cache.pos_view(),
                                  cache.mapped_mask(), axis_name=tp_axis)


def paged_attention(q, cache: PagedLayerCache, *, cur_pos, window: int = 0,
                    scale: float | None = None, num_splits: int = 1,
                    return_scores: bool = False, tp_axis: str | None = None):
    """Decode attention over a pooled paged cache via the Pallas kernel.

    q: (B, H, hd) current-token queries -> (B, H, hd), or
    ``(out, page_scores)`` with page_scores (B, P) when ``return_scores``
    (the fused eviction-score epilogue, DESIGN.md §8). ``num_splits``
    partitions the logical-page walk into independent split-K chunks
    (long-context decode latency; DESIGN.md §8).
    """
    B, H, hd = q.shape
    KV = cache.k.shape[2]
    G = H // KV
    if cache.quantized:
        # int8-native: K/V stream to VMEM as int8 and dequantize in-register
        # (HBM traffic ~0.53x of bf16 — the quantized-KV composition the
        # paper cites as future work)
        from repro.kernels.paged_attention import paged_attention_kernel_int8
        res = paged_attention_kernel_int8(
            q.reshape(B, KV, G, hd),
            _pool_layout(cache.k), _pool_layout(cache.v),
            jnp.moveaxis(cache.k_scale, 2, 0),
            jnp.moveaxis(cache.v_scale, 2, 0),
            cache.pos, cache.block_table, cur_pos,
            window=window, scale=scale, interpret=INTERPRET,
            num_splits=num_splits, return_scores=return_scores)
    else:
        res = paged_attention_kernel(
            q.reshape(B, KV, G, hd),
            _pool_layout(cache.k), _pool_layout(cache.v),
            cache.pos, cache.block_table, cur_pos,
            window=window, scale=scale, interpret=INTERPRET,
            num_splits=num_splits, return_scores=return_scores)
    if return_scores:
        out, norms = res
        return out.reshape(B, H, hd), _epilogue_scores(cache, norms, tp_axis)
    return res.reshape(B, H, hd)


def paged_prefill_attention(q, cache: PagedLayerCache, *, q_pos,
                            window: int = 0, scale: float | None = None,
                            return_scores: bool = False,
                            tp_axis: str | None = None):
    """Chunked-prefill attention over a pooled paged cache via the Pallas
    paged flash-prefill kernel (the unified-step hot path, G-fold fetch).

    q: (B, T, H, hd) chunk queries; q_pos: (B, T) int32 (-1 == padding)
    -> (B, T, H, hd), or ``(out, page_scores)`` with page_scores (B, P)
    when ``return_scores``. The chunk's K/V must already be appended to
    the pool (write-then-attend). int8 caches dequantize pool-side before
    the call (the chunk kernel is f32-tile only; an int8-native variant is
    the same follow-up the decode kernel already landed)."""
    if cache.quantized:
        k_pool, v_pool = cache.k_dequant(), cache.v_dequant()
    else:
        k_pool, v_pool = cache.k, cache.v
    res = paged_flash_prefill_kernel(
        q, _pool_layout(k_pool), _pool_layout(v_pool),
        cache.pos, cache.block_table, q_pos,
        window=window, scale=scale, interpret=INTERPRET,
        return_scores=return_scores)
    if return_scores:
        out, norms = res
        return out, _epilogue_scores(cache, norms, tp_axis)
    return res


def page_scores(cache: PagedLayerCache):
    """Standalone page scoring (paper Alg.1 block mode): (B, P) f32. Each
    physical page is reduced once on the pool, then gathered per request.

    Since the fused epilogue (DESIGN.md §8) this is the slow/oracle path —
    the hot paths get the same scores as attention byproducts. int8 pools
    dequantize first so both paths score identical values (the kernels'
    epilogue norms are taken on dequantized VMEM tiles)."""
    if cache.quantized:
        k_pool, v_pool = cache.k_dequant(), cache.v_dequant()
    else:
        k_pool, v_pool = cache.k, cache.v
    pool = block_score_kernel(k_pool, v_pool, cache.pos,
                              interpret=INTERPRET)          # (N,)
    return jnp.where(cache.mapped_mask(),
                     jnp.take(pool, jnp.maximum(cache.block_table, 0)),
                     jnp.inf)


def flash_attention(q, k, v, *, window: int = 0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128):
    """Causal GQA flash attention. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    return flash_attention_kernel(q, k, v, window=window, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=INTERPRET)
