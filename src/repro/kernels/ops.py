"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the body
executes in Python via the Pallas interpreter); on a real TPU set
``repro.kernels.ops.INTERPRET = False`` (or env REPRO_PALLAS_COMPILE=1) and
the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.core.paged_cache import PagedLayerCache
from repro.kernels.block_score import block_score_kernel
from repro.kernels.flash_prefill import flash_attention_kernel
from repro.kernels.paged_attention import paged_attention_kernel

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def paged_attention(q, cache: PagedLayerCache, *, cur_pos, window: int = 0,
                    scale: float | None = None):
    """Decode attention over a paged cache via the Pallas kernel.

    q: (B, H, hd) current-token queries -> (B, H, hd).
    """
    B, H, hd = q.shape
    KV = cache.k.shape[3]
    G = H // KV
    # cache slab (B, P, page, KV, hd) -> kernel layout (B, KV, P, page, hd)
    if cache.quantized:
        # int8-native: K/V stream to VMEM as int8 and dequantize in-register
        # (HBM traffic ~0.53x of bf16 — the quantized-KV composition the
        # paper cites as future work)
        from repro.kernels.paged_attention import paged_attention_kernel_int8
        out = paged_attention_kernel_int8(
            q.reshape(B, KV, G, hd),
            jnp.moveaxis(cache.k, 3, 1), jnp.moveaxis(cache.v, 3, 1),
            jnp.moveaxis(cache.k_scale, 3, 1),
            jnp.moveaxis(cache.v_scale, 3, 1),
            cache.pos, cur_pos, window=window, scale=scale,
            interpret=INTERPRET)
        return out.reshape(B, H, hd)
    k_pages = jnp.moveaxis(cache.k, 3, 1)
    v_pages = jnp.moveaxis(cache.v, 3, 1)
    out = paged_attention_kernel(
        q.reshape(B, KV, G, hd), k_pages, v_pages, cache.pos, cur_pos,
        window=window, scale=scale, interpret=INTERPRET)
    return out.reshape(B, H, hd)


def page_scores(cache: PagedLayerCache):
    """Fused page scoring (paper Alg.1 block mode): (B, P) f32."""
    return block_score_kernel(cache.k, cache.v, cache.pos, interpret=INTERPRET)


def flash_attention(q, k, v, *, window: int = 0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128):
    """Causal GQA flash attention. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    return flash_attention_kernel(q, k, v, window=window, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=INTERPRET)
