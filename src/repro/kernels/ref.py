"""Pure-jnp oracles for every Pallas kernel (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-6


def paged_attention_ref(q, k_pages, v_pages, pos, cur_pos, *, window: int = 0,
                        scale: float | None = None):
    """Dense per-request paged attention oracle (no indirection).

    q: (B, KV, G, hd); k_pages/v_pages: (B, KV, P, page, hd);
    pos: (B, P, page); cur_pos: (B,) -> (B, KV, G, hd).
    """
    B, KV, G, hd = q.shape
    P, page = k_pages.shape[2], k_pages.shape[3]
    scale = scale if scale is not None else hd ** -0.5
    kf = k_pages.reshape(B, KV, P * page, hd).astype(jnp.float32)
    vf = v_pages.reshape(B, KV, P * page, hd).astype(jnp.float32)
    pf = pos.reshape(B, P * page)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32), kf) * scale
    mask = (pf >= 0) & (pf <= cur_pos[:, None])
    if window > 0:
        mask &= pf > (cur_pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bkgs,bksd->bkgd", p, vf).astype(q.dtype)


def gather_block_table(k_pool, v_pool, pos, block_table):
    """Materialize the per-request dense view of a page pool.

    k_pool/v_pool: (KV, N, page, hd); pos: (N, page); block_table: (B, P)
    -> k/v (B, KV, P, page, hd), pos (B, P, page) with unmapped slots -1.
    The gather the Pallas kernel avoids — used only to feed the dense oracle.
    """
    mapped = block_table >= 0                        # (B, P)
    phys = jnp.maximum(block_table, 0)
    kg = jnp.moveaxis(jnp.take(k_pool, phys, axis=1), 0, 1)  # (B, KV, P, page, hd)
    vg = jnp.moveaxis(jnp.take(v_pool, phys, axis=1), 0, 1)
    pg = jnp.where(mapped[..., None], jnp.take(pos, phys, axis=0), -1)
    return kg, vg, pg


def paged_attention_block_table_ref(q, k_pool, v_pool, pos, block_table,
                                    cur_pos, *, window: int = 0,
                                    scale: float | None = None):
    """Same signature/layout as paged_attention.paged_attention_kernel:
    gather the pool through the block table, then run the dense oracle."""
    kg, vg, pg = gather_block_table(k_pool, v_pool, pos, block_table)
    return paged_attention_ref(q, kg, vg, pg, cur_pos, window=window,
                               scale=scale)


def paged_prefill_attention_ref(q, k_pages, v_pages, pos, q_pos, *,
                                window: int = 0, scale: float | None = None):
    """Dense chunked-prefill attention oracle: a contiguous chunk of queries
    per request attends over that request's paged K/V (which already
    contains the chunk's own tokens — write-then-attend, so intra-chunk
    causality falls out of the position mask).

    q: (B, T, KV, G, hd); k_pages/v_pages: (B, KV, P, page, hd);
    pos: (B, P, page); q_pos: (B, T) int32 (-1 == padding query)
    -> (B, T, KV, G, hd). Padding queries return zeros.
    """
    B, T, KV, G, hd = q.shape
    P, page = k_pages.shape[2], k_pages.shape[3]
    scale = scale if scale is not None else hd ** -0.5
    kf = k_pages.reshape(B, KV, P * page, hd).astype(jnp.float32)
    vf = v_pages.reshape(B, KV, P * page, hd).astype(jnp.float32)
    pf = pos.reshape(B, P * page)
    s = jnp.einsum("btkgd,bksd->bkgts", q.astype(jnp.float32), kf) * scale
    mask = (pf[:, None, :] >= 0) & (pf[:, None, :] <= q_pos[:, :, None]) & \
        (q_pos[:, :, None] >= 0)                            # (B, T, S)
    if window > 0:
        mask &= pf[:, None, :] > (q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgts,bksd->btkgd", p, vf)
    return o.astype(q.dtype)


def paged_prefill_attention_block_table_ref(q, k_pool, v_pool, pos,
                                            block_table, q_pos, *,
                                            window: int = 0,
                                            scale: float | None = None):
    """Same signature/layout as flash_prefill.paged_flash_prefill_kernel:
    gather the pool through the block table, then run the dense oracle."""
    kg, vg, pg = gather_block_table(k_pool, v_pool, pos, block_table)
    return paged_prefill_attention_ref(q, kg, vg, pg, q_pos, window=window,
                                       scale=scale)


def block_score_ref(k_pages, v_pages, pos):
    """k_pages, v_pages: (..., page, KV, hd); pos: (..., page) -> (...,).
    Works on the physical pool layout (N, page, KV, hd) -> (N,) as well as
    gathered per-request views (B, P, page, KV, hd) -> (B, P)."""
    kn = jnp.linalg.norm(k_pages.astype(jnp.float32), axis=-1)  # (B,P,page,KV)
    vn = jnp.linalg.norm(v_pages.astype(jnp.float32), axis=-1)
    tok = jnp.mean(vn, axis=-1) / jnp.maximum(jnp.mean(kn, axis=-1), _EPS)
    valid = pos >= 0
    cnt = jnp.sum(valid, axis=-1)
    ssum = jnp.sum(jnp.where(valid, tok, 0.0), axis=-1)
    return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)


def page_scores_ref(cache):
    """Per-request Alg.1 page scores from the GATHERED (dequantized) view:
    (B, P) f32, unmapped/empty pages +inf. The per-request-view oracle for
    both the standalone pool pass (ops.page_scores) and the fused attention
    epilogue (importance.page_scores_from_norms); materializes the gather
    the kernels avoid, so tests — only."""
    scores = block_score_ref(cache.k_view(), cache.v_view(),
                             cache.pos_view())               # (B, P)
    return jnp.where(cache.mapped_mask(), scores, jnp.inf)


def flash_attention_ref(q, k, v, *, window: int = 0, scale: float | None = None):
    """Causal GQA attention oracle. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)
    mask = qpos[None, :, None] >= qpos[None, None, :]       # (1, Sq, Sk)
    if window > 0:
        mask &= qpos[None, None, :] > (qpos[None, :, None] - window)
    s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
