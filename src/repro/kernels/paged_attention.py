"""Pallas TPU paged-attention decode kernel with block-table indirection.

The TPU-native replacement for vLLM's CUDA PagedAttention (DESIGN.md §2):
one query token per request attends over the SHARED page pool, walking its
block table page by page, with flash (online-softmax) accumulation in VMEM
scratch.

Grid: (batch, kv_head, split, page_in_split) — SPLIT-K flash-decode
(DESIGN.md §8). Long-context decode is latency-bound on a single query
token walking pages serially, so the logical-page axis is partitioned into
``num_splits`` independent chunks. Each chunk accumulates its OWN
(m, l, acc) flash state over its page range (TPU grid execution is
sequential over the minor-most dimension, so the scratch accumulates across
``page_in_split`` and resets at each split boundary), and the kernel emits
the UN-normalized partial state per split. A second lightweight combine
step (plain jnp in the wrapper — O(S·G·hd) elementwise, negligible next to
the page walk) rescales the partials to a common max and normalizes:

    m* = max_s m_s;  o = Σ_s e^{m_s − m*}·acc_s / Σ_s e^{m_s − m*}·l_s

— the xformers ``ops/fmha/triton.py`` split-K idiom ported to the Pallas
TPU sequential-grid model. On hardware the split axis is embarrassingly
parallel (no scratch carried across it), so ``num_splits`` shortens the
serial chain from P to ceil(P/S) page steps; ``num_splits=1`` reproduces
the old single-chain walk exactly (the combine degenerates to the old
finalize's ``acc / max(l, eps)``). Empty splits are safe by construction:
they emit m = NEG_INF, l = 0, acc = 0, and e^{NEG_INF − m*} underflows to
exactly 0 in the combine.

Indirection is gather-free: the block table rides in as a scalar-prefetch
operand (``pltpu.PrefetchScalarGridSpec``), so each BlockSpec ``index_map``
reads ``bt[b, p]`` and DMAs exactly one (page_size, head_dim) physical K/V
tile from the pool — the working set is O(page) regardless of context
length or pool size, and no (B, P, page, ...) gathered copy of the cache is
ever materialized. Unmapped slots (bt[b, p] < 0) clamp their DMA to pool
page 0 and are masked inside the kernel body via the same scalar ref —
essential, because a freed physical page may already hold ANOTHER request's
live tokens. The masking is per (b, h, split, i) step, so freed/reallocated
pages stay correctly masked no matter which split walks them. Logical pages
past P (padding steps when P % num_splits != 0) clamp to slot P - 1 and
mask everything — they contribute exactly nothing.

Fused score epilogue (``return_scores=True``): the K/V tiles are already
live in VMEM (dequantized for int8 pools), so the per-token L2 norms that
``kernels/block_score.py`` recomputes in a separate full pass over the pool
come out as byproduct outputs kn/vn: (B, KV, P, page) — one (1, page) tile
per (b, h, p) step, written unmasked (the wrapper-side combine masks by
block table + pos and reduces to the paper's Alg.1 page score, see
``importance.page_scores_from_norms``). Eviction metadata is then free:
zero extra HBM reads, one extra VPU reduction per tile the kernel already
fetched. The standalone ``block_score`` kernel survives only as the parity
oracle.

Prefix sharing (DESIGN.md §7) needs no extra masking here: a physical page
mapped under several block tables is always a COMPLETE prompt-prefix page
holding the SAME positions [slot*page, (slot+1)*page) for every mapper (the
adoption probe enforces it), so the existing mapped / pos >= 0 / pos <=
cur_pos masks are already correct for shared pages. What sharing does rule
out is any assumption that bt rows are disjoint — two requests' tables may
point the same tile, and the kernel must treat each (b, p) step
independently (it does: all per-step state is derived from bt[b, p]).
Epilogue outputs are indexed by LOGICAL slot (b, p), so two sharers of one
physical page each write their own copy of its norms — identical values,
no conflict.

Layout: the wrapper (ops.py) permutes the pool to (KV, N_pool, page, hd) so
each block is a contiguous (page, hd) tile — page_size 16 x head_dim 128 is
MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_update(s, valid, v, m_scr, l_scr, acc_scr):
    """One online-softmax update of the (m, l, acc) scratch state.

    s: (rows, page) masked scores; valid: (rows, page) bool; v: (page, hd).
    """
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[:, 0:1]                              # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    pexp = jnp.where(valid, pexp, 0.0)
    l_new = alpha * l_scr[:, 0:1] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc_new


def _decode_step_body(bt_ref, q_ref, k, v, pos_ref, curpos_ref, refs, *,
                      pages_per_split: int, num_pages: int, window: int,
                      scale: float, with_scores: bool):
    """Shared split-K body for the f32 and int8 decode kernels. ``k``/``v``
    arrive as dequantized f32 (page, hd) tiles."""
    if with_scores:
        acc_ref, m_ref, l_ref, kn_ref, vn_ref, m_scr, l_scr, acc_scr = refs
    else:
        acc_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    sp = pl.program_id(2)
    i = pl.program_id(3)
    p = sp * pages_per_split + i                        # logical page slot
    pc = jnp.minimum(p, num_pages - 1)                  # clamped (padding)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (G, hd)
    pos = pos_ref[0, :]                                 # (page,) int32
    cur = curpos_ref[0, 0]
    # this step's slot holds a live page AND is not split padding
    mapped = (p < num_pages) & (bt_ref[b, pc] >= 0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = mapped & (pos >= 0) & (pos <= cur)
    if window > 0:
        valid &= pos > (cur - window)
    _flash_update(s, valid[None, :], v, m_scr, l_scr, acc_scr)

    if with_scores:
        # byproduct epilogue: per-token K/V norms of the tile already in
        # VMEM. Padding steps (p >= P) recompute slot P-1's tile (the DMA
        # clamps the same way) and rewrite identical values — no guard
        # needed. Masking/means happen wrapper-side.
        kn_ref[0, :] = jnp.sqrt(jnp.sum(k * k, axis=-1))
        vn_ref[0, :] = jnp.sqrt(jnp.sum(v * v, axis=-1))

    @pl.when(i == pages_per_split - 1)
    def _finalize():
        # UN-normalized split partials; the wrapper's combine step reduces
        # across splits (num_splits == 1 degenerates to plain normalization)
        acc_ref[...] = acc_scr[...]
        m_ref[...] = m_scr[...]
        l_ref[...] = l_scr[...]


def _paged_attn_kernel(bt_ref, q_ref, k_ref, v_ref, pos_ref, curpos_ref,
                       *refs, pages_per_split: int, num_pages: int,
                       window: int, scale: float, with_scores: bool):
    """One (batch, kv_head, split, page_in_split) step.

    bt_ref  : (B, P) int32 block tables (scalar prefetch, SMEM)
    q_ref   : (G, hd)      this kv-head's query group
    k_ref   : (page, hd)   one PHYSICAL page of keys (block-table indexed)
    v_ref   : (page, hd)   one physical page of values
    pos_ref : (1, page)    token positions of that physical page (-1 invalid)
    curpos_ref : (1, 1)    current decode position
    outputs : acc (G, hd), m (G, 128), l (G, 128) split partials (written on
              the split's last page step); with_scores adds kn/vn (1, page)
    scratch : m (G, 128), l (G, 128), acc (G, hd) f32
    """
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    _decode_step_body(bt_ref, q_ref, k, v, pos_ref, curpos_ref, refs,
                      pages_per_split=pages_per_split, num_pages=num_pages,
                      window=window, scale=scale, with_scores=with_scores)


def _paged_attn_kernel_int8(bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                            pos_ref, curpos_ref, *refs, pages_per_split: int,
                            num_pages: int, window: int, scale: float,
                            with_scores: bool):
    """int8 variant: K/V tiles arrive quantized; dequantization happens in
    VMEM (one multiply per tile) so HBM traffic is the int8 bytes + scales —
    the fused memory win the paper's future-work section points at. The
    fused epilogue norms are computed on the DEQUANTIZED tiles, so they
    match ``block_score`` of the dequantized pool.

    ks_ref, vs_ref: (1, page) f32 absmax scales for this physical page."""
    k = k_ref[...].astype(jnp.float32) * (ks_ref[0, :] / 127.0)[:, None]
    v = v_ref[...].astype(jnp.float32) * (vs_ref[0, :] / 127.0)[:, None]
    _decode_step_body(bt_ref, q_ref, k, v, pos_ref, curpos_ref, refs,
                      pages_per_split=pages_per_split, num_pages=num_pages,
                      window=window, scale=scale, with_scores=with_scores)


def _pool_index(bt_ref, b, p):
    """Physical page id for (request b, logical slot p); clamped so unmapped
    slots DMA pool page 0 (masked in the kernel body)."""
    return jnp.maximum(bt_ref[b, p], 0)


def combine_splits(acc, m, l):
    """Reduce split-K partial softmaxes to the final attention output.

    acc: (B, KV, S, G, hd) un-normalized partial values; m/l: (B, KV, S, G,
    lanes) split max / normalizer (lane-broadcast; lane 0 is read).
    -> (B, KV, G, hd) f32. Empty splits (m == NEG_INF, l == 0) contribute
    exactly 0; a fully-empty row divides 0 by the 1e-30 floor -> zeros,
    matching the single-chain kernel's finalize."""
    m = m[..., 0]                                       # (B, KV, S, G)
    l = l[..., 0]
    m_max = jnp.max(m, axis=2)                          # (B, KV, G)
    coef = jnp.exp(m - m_max[:, :, None, :])            # (B, KV, S, G)
    l_tot = jnp.sum(coef * l, axis=2)                   # (B, KV, G)
    o = jnp.sum(coef[..., None] * acc, axis=2)          # (B, KV, G, hd)
    return o / jnp.maximum(l_tot, 1e-30)[..., None]


def _split_grid(P: int, num_splits: int):
    S = max(1, min(int(num_splits), P))
    return S, -(-P // S)                                # (splits, pages/split)


def _decode_out_shapes(B, KV, S, G, hd, P, page, with_scores):
    shapes = [
        jax.ShapeDtypeStruct((B, KV, S, G, hd), jnp.float32),   # acc
        jax.ShapeDtypeStruct((B, KV, S, G, 128), jnp.float32),  # m
        jax.ShapeDtypeStruct((B, KV, S, G, 128), jnp.float32),  # l
    ]
    if with_scores:
        shapes += [jax.ShapeDtypeStruct((B, KV, P, page), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, P, page), jnp.float32)]
    return tuple(shapes)


def _decode_out_specs(G, hd, P, page, pps, with_scores):
    part = lambda b, h, sp, i, bt: (b, h, sp, 0, 0)
    specs = [
        pl.BlockSpec((None, None, None, G, hd), part),
        pl.BlockSpec((None, None, None, G, 128), part),
        pl.BlockSpec((None, None, None, G, 128), part),
    ]
    if with_scores:
        norm = lambda b, h, sp, i, bt: \
            (b, h, jnp.minimum(sp * pps + i, P - 1), 0)
        specs += [pl.BlockSpec((None, None, 1, page), norm),
                  pl.BlockSpec((None, None, 1, page), norm)]
    return tuple(specs)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "interpret", "num_splits", "return_scores"))
def paged_attention_kernel(q, k_pool, v_pool, pos, block_table, cur_pos, *,
                           window: int = 0, scale: float | None = None,
                           interpret: bool = True, num_splits: int = 1,
                           return_scores: bool = False):
    """q: (B, KV, G, hd); k_pool/v_pool: (KV, N_pool, page, hd);
    pos: (N_pool, page) int32; block_table: (B, P) int32;
    cur_pos: (B,) int32 -> (B, KV, G, hd) [, (kn, vn) each (B, KV, P, page)
    when ``return_scores``].

    ``num_splits``: split-K factor — the page walk runs as ceil(P/S)
    sequential steps per split instead of P, with a jnp combine across
    splits. 1 == the classic single-chain walk (bit-compatible combine)."""
    B, KV, G, hd = q.shape
    page = k_pool.shape[2]
    P = block_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    S, pps = _split_grid(P, num_splits)
    kernel = functools.partial(_paged_attn_kernel, pages_per_split=pps,
                               num_pages=P, window=window, scale=scale,
                               with_scores=return_scores)

    def kv_map(b, h, sp, i, bt):
        return (h, _pool_index(bt, b, jnp.minimum(sp * pps + i, P - 1)), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, S, pps),
        in_specs=[
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, sp, i, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((1, page),
                         lambda b, h, sp, i, bt:
                         (_pool_index(bt, b,
                                      jnp.minimum(sp * pps + i, P - 1)), 0)),
            pl.BlockSpec((1, 1), lambda b, h, sp, i, bt: (b, 0)),
        ],
        out_specs=_decode_out_specs(G, hd, P, page, pps, return_scores),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=_decode_out_shapes(B, KV, S, G, hd, P, page, return_scores),
        interpret=interpret,
    )(block_table, q.reshape(B, KV, G, hd), k_pool, v_pool, pos,
      cur_pos.reshape(B, 1))
    out = combine_splits(res[0], res[1], res[2]).astype(q.dtype)
    if return_scores:
        return out, (res[3], res[4])
    return out


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "interpret", "num_splits", "return_scores"))
def paged_attention_kernel_int8(q, k_pool, v_pool, k_scales, v_scales, pos,
                                block_table, cur_pos, *, window: int = 0,
                                scale: float | None = None,
                                interpret: bool = True, num_splits: int = 1,
                                return_scores: bool = False):
    """q: (B, KV, G, hd) f32/bf16; k_pool/v_pool: (KV, N_pool, page, hd) int8;
    k_scales/v_scales: (KV, N_pool, page) f32; pos: (N_pool, page) int32;
    block_table: (B, P) int32. Split-K + fused epilogue as the f32 kernel;
    epilogue norms are of the dequantized tiles."""
    B, KV, G, hd = q.shape
    page = k_pool.shape[2]
    P = block_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    S, pps = _split_grid(P, num_splits)
    kernel = functools.partial(_paged_attn_kernel_int8, pages_per_split=pps,
                               num_pages=P, window=window, scale=scale,
                               with_scores=return_scores)

    def pmap(b, h, sp, i, bt):
        return _pool_index(bt, b, jnp.minimum(sp * pps + i, P - 1))

    def kv_map(b, h, sp, i, bt):
        return (h, pmap(b, h, sp, i, bt), 0, 0)

    def scale_map(b, h, sp, i, bt):
        return (h, pmap(b, h, sp, i, bt), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, S, pps),
        in_specs=[
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, sp, i, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, 1, page), scale_map),
            pl.BlockSpec((None, 1, page), scale_map),
            pl.BlockSpec((1, page),
                         lambda b, h, sp, i, bt: (pmap(b, h, sp, i, bt), 0)),
            pl.BlockSpec((1, 1), lambda b, h, sp, i, bt: (b, 0)),
        ],
        out_specs=_decode_out_specs(G, hd, P, page, pps, return_scores),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=_decode_out_shapes(B, KV, S, G, hd, P, page, return_scores),
        interpret=interpret,
    )(block_table, q.reshape(B, KV, G, hd), k_pool, v_pool, k_scales,
      v_scales, pos, cur_pos.reshape(B, 1))
    out = combine_splits(res[0], res[1], res[2]).astype(q.dtype)
    if return_scores:
        return out, (res[3], res[4])
    return out
