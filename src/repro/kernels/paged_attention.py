"""Pallas TPU paged-attention decode kernel with block-table indirection.

The TPU-native replacement for vLLM's CUDA PagedAttention (DESIGN.md §2):
one query token per request attends over the SHARED page pool, walking its
block table page by page, with flash (online-softmax) accumulation in VMEM
scratch.

Grid: (batch, kv_head, logical_page). TPU grid execution is sequential over
the minor-most dimension, so the (m, l, acc) scratch accumulates across the
page axis; output is written on the last page step.

Indirection is gather-free: the block table rides in as a scalar-prefetch
operand (``pltpu.PrefetchScalarGridSpec``), so each BlockSpec ``index_map``
reads ``bt[b, p]`` and DMAs exactly one (page_size, head_dim) physical K/V
tile from the pool — the working set is O(page) regardless of context
length or pool size, and no (B, P, page, ...) gathered copy of the cache is
ever materialized. Unmapped slots (bt[b, p] < 0) clamp their DMA to pool
page 0 and are masked inside the kernel body via the same scalar ref —
essential, because a freed physical page may already hold ANOTHER request's
live tokens.

Prefix sharing (DESIGN.md §7) needs no extra masking here: a physical page
mapped under several block tables is always a COMPLETE prompt-prefix page
holding the SAME positions [slot*page, (slot+1)*page) for every mapper (the
adoption probe enforces it), so the existing mapped / pos >= 0 / pos <=
cur_pos masks are already correct for shared pages. What sharing does rule
out is any assumption that bt rows are disjoint — two requests' tables may
point the same tile, and the kernel must treat each (b, p) step
independently (it does: all per-step state is derived from bt[b, p]).

Layout: the wrapper (ops.py) permutes the pool to (KV, N_pool, page, hd) so
each block is a contiguous (page, hd) tile — page_size 16 x head_dim 128 is
MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(bt_ref, q_ref, k_ref, v_ref, pos_ref, curpos_ref,
                       o_ref, m_scr, l_scr, acc_scr, *, num_pages: int,
                       window: int, scale: float):
    """One (batch, kv_head, logical_page) step.

    bt_ref  : (B, P) int32 block tables (scalar prefetch, SMEM)
    q_ref   : (G, hd)      this kv-head's query group
    k_ref   : (page, hd)   one PHYSICAL page of keys (block-table indexed)
    v_ref   : (page, hd)   one physical page of values
    pos_ref : (1, page)    token positions of that physical page (-1 invalid)
    curpos_ref : (1, 1)    current decode position
    o_ref   : (G, hd)      output (written on the last page step)
    scratch : m (G, 128), l (G, 128), acc (G, hd) f32
    """
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (G, hd)
    k = k_ref[...].astype(jnp.float32)                  # (page, hd)
    v = v_ref[...].astype(jnp.float32)                  # (page, hd)
    pos = pos_ref[0, :]                                 # (page,) int32
    cur = curpos_ref[0, 0]
    mapped = bt_ref[b, p] >= 0                          # this slot holds a page

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = mapped & (pos >= 0) & (pos <= cur)
    if window > 0:
        valid &= pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)           # (G, page)

    m_prev = m_scr[:, 0:1]                              # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                     # (G, 1)
    pexp = jnp.exp(s - m_new)                           # (G, page)
    pexp = jnp.where(valid[None, :], pexp, 0.0)
    l_new = alpha * l_scr[:, 0:1] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc_new

    @pl.when(p == num_pages - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


def _paged_attn_kernel_int8(bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                            pos_ref, curpos_ref, o_ref, m_scr, l_scr, acc_scr,
                            *, num_pages: int, window: int, scale: float):
    """int8 variant: K/V tiles arrive quantized; dequantization happens in
    VMEM (one multiply per tile) so HBM traffic is the int8 bytes + scales —
    the fused memory win the paper's future-work section points at.

    ks_ref, vs_ref: (1, page) f32 absmax scales for this physical page."""
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32) * (ks_ref[0, :] / 127.0)[:, None]
    v = v_ref[...].astype(jnp.float32) * (vs_ref[0, :] / 127.0)[:, None]
    pos = pos_ref[0, :]
    cur = curpos_ref[0, 0]
    mapped = bt_ref[b, p] >= 0

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = mapped & (pos >= 0) & (pos <= cur)
    if window > 0:
        valid &= pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[:, 0:1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    pexp = jnp.where(valid[None, :], pexp, 0.0)
    l_new = alpha * l_scr[:, 0:1] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc_new

    @pl.when(p == num_pages - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


def _pool_index(bt_ref, b, p):
    """Physical page id for (request b, logical slot p); clamped so unmapped
    slots DMA pool page 0 (masked in the kernel body)."""
    return jnp.maximum(bt_ref[b, p], 0)


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def paged_attention_kernel(q, k_pool, v_pool, pos, block_table, cur_pos, *,
                           window: int = 0, scale: float | None = None,
                           interpret: bool = True):
    """q: (B, KV, G, hd); k_pool/v_pool: (KV, N_pool, page, hd);
    pos: (N_pool, page) int32; block_table: (B, P) int32;
    cur_pos: (B,) int32 -> (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    page = k_pool.shape[2]
    P = block_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(_paged_attn_kernel, num_pages=P, window=window,
                               scale=scale)

    def kv_map(b, h, p, bt):
        return (h, _pool_index(bt, b, p), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((None, None, G, hd), lambda b, h, p, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((1, page),
                         lambda b, h, p, bt: (_pool_index(bt, b, p), 0)),
            pl.BlockSpec((1, 1), lambda b, h, p, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, p, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, q.reshape(B, KV, G, hd), k_pool, v_pool, pos,
      cur_pos.reshape(B, 1))


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def paged_attention_kernel_int8(q, k_pool, v_pool, k_scales, v_scales, pos,
                                block_table, cur_pos, *, window: int = 0,
                                scale: float | None = None,
                                interpret: bool = True):
    """q: (B, KV, G, hd) f32/bf16; k_pool/v_pool: (KV, N_pool, page, hd) int8;
    k_scales/v_scales: (KV, N_pool, page) f32; pos: (N_pool, page) int32;
    block_table: (B, P) int32."""
    B, KV, G, hd = q.shape
    page = k_pool.shape[2]
    P = block_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(_paged_attn_kernel_int8, num_pages=P,
                               window=window, scale=scale)

    def kv_map(b, h, p, bt):
        return (h, _pool_index(bt, b, p), 0, 0)

    def scale_map(b, h, p, bt):
        return (h, _pool_index(bt, b, p), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((None, None, G, hd), lambda b, h, p, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, 1, page), scale_map),
            pl.BlockSpec((None, 1, page), scale_map),
            pl.BlockSpec((1, page),
                         lambda b, h, p, bt: (_pool_index(bt, b, p), 0)),
            pl.BlockSpec((1, 1), lambda b, h, p, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, p, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, q.reshape(B, KV, G, hd), k_pool, v_pool, k_scales,
      v_scales, pos, cur_pos.reshape(B, 1))
