"""Pallas TPU paged-attention decode kernel.

The TPU-native replacement for vLLM's CUDA PagedAttention (DESIGN.md §2):
one query token per request attends over the paged KV cache, page by page,
with flash (online-softmax) accumulation in VMEM scratch.

Grid: (batch, kv_head, page). TPU grid execution is sequential over the
minor-most dimension, so the (m, l, acc) scratch accumulates across the
page axis; output is written on the last page step. Pages stream
HBM -> VMEM one (page_size, head_dim) tile per K and V — the working set is
O(page) regardless of context length, and evicted pages are skipped by the
position mask (pos < 0), never touched by a gather.

Layout: the wrapper (ops.py) permutes the cache slab to (B, KV, P, page, hd)
so each block is a contiguous (page, hd) tile — page_size 16 x head_dim 128
is MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(q_ref, k_ref, v_ref, pos_ref, curpos_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, num_pages: int, window: int,
                       scale: float):
    """One (batch, kv_head, page) step.

    q_ref   : (G, hd)      this kv-head's query group
    k_ref   : (page, hd)   one page of keys
    v_ref   : (page, hd)   one page of values
    pos_ref : (1, page)    token positions (-1 == evicted/invalid)
    curpos_ref : (1, 1)    current decode position
    o_ref   : (G, hd)      output (written on the last page step)
    scratch : m (G, 128), l (G, 128), acc (G, hd) f32
    """
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (G, hd)
    k = k_ref[...].astype(jnp.float32)                  # (page, hd)
    v = v_ref[...].astype(jnp.float32)                  # (page, hd)
    pos = pos_ref[0, :]                                 # (page,) int32
    cur = curpos_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (pos >= 0) & (pos <= cur)
    if window > 0:
        valid &= pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)           # (G, page)

    m_prev = m_scr[:, 0:1]                              # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                     # (G, 1)
    pexp = jnp.exp(s - m_new)                           # (G, page)
    pexp = jnp.where(valid[None, :], pexp, 0.0)
    l_new = alpha * l_scr[:, 0:1] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc_new

    @pl.when(p == num_pages - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


def _paged_attn_kernel_int8(q_ref, k_ref, v_ref, ks_ref, vs_ref, pos_ref,
                            curpos_ref, o_ref, m_scr, l_scr, acc_scr, *,
                            num_pages: int, window: int, scale: float):
    """int8 variant: K/V tiles arrive quantized; dequantization happens in
    VMEM (one multiply per tile) so HBM traffic is the int8 bytes + scales —
    the fused memory win the paper's future-work section points at.

    ks_ref, vs_ref: (1, page) f32 absmax scales for this page."""
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32) * (ks_ref[0, :] / 127.0)[:, None]
    v = v_ref[...].astype(jnp.float32) * (vs_ref[0, :] / 127.0)[:, None]
    pos = pos_ref[0, :]
    cur = curpos_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (pos >= 0) & (pos <= cur)
    if window > 0:
        valid &= pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[:, 0:1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    pexp = jnp.where(valid[None, :], pexp, 0.0)
    l_new = alpha * l_scr[:, 0:1] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc_new

    @pl.when(p == num_pages - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def paged_attention_kernel_int8(q, k_pages, v_pages, k_scales, v_scales, pos,
                                cur_pos, *, window: int = 0,
                                scale: float | None = None,
                                interpret: bool = True):
    """q: (B, KV, G, hd) f32/bf16; k_pages/v_pages: (B, KV, P, page, hd) int8;
    k_scales/v_scales: (B, KV, P, page) f32; pos: (B, P, page) int32."""
    B, KV, G, hd = q.shape
    P, page = k_pages.shape[2], k_pages.shape[3]
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(_paged_attn_kernel_int8, num_pages=P,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((None, None, G, hd), lambda b, h, p: (b, h, 0, 0)),
            pl.BlockSpec((None, None, None, page, hd),
                         lambda b, h, p: (b, h, p, 0, 0)),
            pl.BlockSpec((None, None, None, page, hd),
                         lambda b, h, p: (b, h, p, 0, 0)),
            pl.BlockSpec((None, None, 1, page), lambda b, h, p: (b, h, p, 0)),
            pl.BlockSpec((None, None, 1, page), lambda b, h, p: (b, h, p, 0)),
            pl.BlockSpec((None, 1, page), lambda b, h, p: (b, p, 0)),
            pl.BlockSpec((1, 1), lambda b, h, p: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd), lambda b, h, p: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B, KV, G, hd), k_pages, v_pages, k_scales, v_scales, pos,
      cur_pos.reshape(B, 1))


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def paged_attention_kernel(q, k_pages, v_pages, pos, cur_pos, *, window: int = 0,
                           scale: float | None = None, interpret: bool = True):
    """q: (B, KV, G, hd); k_pages/v_pages: (B, KV, P, page, hd);
    pos: (B, P, page) int32; cur_pos: (B,) int32 -> (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    P, page = k_pages.shape[2], k_pages.shape[3]
    scale = scale if scale is not None else hd ** -0.5

    kernel = functools.partial(_paged_attn_kernel, num_pages=P, window=window,
                               scale=scale)
    grid = (B, KV, P)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, G, hd), lambda b, h, p: (b, h, 0, 0)),
            pl.BlockSpec((None, None, None, page, hd),
                         lambda b, h, p: (b, h, p, 0, 0)),
            pl.BlockSpec((None, None, None, page, hd),
                         lambda b, h, p: (b, h, p, 0, 0)),
            pl.BlockSpec((None, 1, page), lambda b, h, p: (b, p, 0)),
            pl.BlockSpec((1, 1), lambda b, h, p: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd), lambda b, h, p: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(
        q.reshape(B, KV, G, hd),
        k_pages, v_pages,
        pos,
        cur_pos.reshape(B, 1),
    )
    return out
