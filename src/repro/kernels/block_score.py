"""Pallas TPU kernel for the paper's page scoring (Alg. 1, block mode).

Computes S_j = mean_{i in page j, valid} ( mean_h ||V_i|| / mean_h ||K_i|| )
directly from the PHYSICAL page pool. Since the kernel perf pass
(DESIGN.md §8) this standalone pass is OFF the hot paths: the decode and
prefill attention kernels emit the same per-token K/V norms as a byproduct
epilogue (the tiles are already in VMEM), and
``importance.page_scores_from_norms`` reduces them to identical page
scores for free. This kernel survives as the parity oracle
(tests/test_kernel_perf.py) and the fallback for windowed layers, whose
fused scores would go stale when out-of-window tokens drop.
Scoring the pool (not per-request views) means each physical page is
reduced exactly once no matter how many block tables map it — the wrapper
(ops.py) gathers pool scores into (B, P) through the block table, and
dequantizes int8 pools first so the oracle matches the epilogue's
dequantized-tile norms.

Grid: (pool_page,). Each step reduces one (page, KV, hd) K and V tile to a
single page score. Empty pages score +inf (never the eviction argmin).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-6


def _block_score_kernel(k_ref, v_ref, pos_ref, o_ref):
    """k_ref, v_ref: (page, KV, hd); pos_ref: (1, page); o_ref: (1, 1)."""
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    pos = pos_ref[0, :]                                    # (page,)
    kn = jnp.sqrt(jnp.sum(k * k, axis=-1))                 # (page, KV)
    vn = jnp.sqrt(jnp.sum(v * v, axis=-1))
    tok = jnp.mean(vn, axis=-1) / jnp.maximum(jnp.mean(kn, axis=-1), _EPS)
    valid = pos >= 0
    cnt = jnp.sum(valid.astype(jnp.float32))
    ssum = jnp.sum(jnp.where(valid, tok, 0.0))
    o_ref[0, 0] = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1.0),
                            jnp.float32(jnp.inf))


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_score_kernel(k_pool, v_pool, pos, *, interpret: bool = True):
    """k_pool, v_pool: (N, page, KV, hd); pos: (N, page) int32
    -> per-physical-page scores (N,) f32."""
    N, page, KV, hd = k_pool.shape
    out = pl.pallas_call(
        _block_score_kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((None, page, KV, hd), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec((None, page, KV, hd), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec((1, page), lambda n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda n: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        interpret=interpret,
    )(k_pool, v_pool, pos)
    return out[:, 0]
