"""Pallas TPU flash-attention kernels for the prefill hot paths.

Three kernels:

``flash_attention_kernel`` — contiguous causal flash (train / offline
whole-prompt prefill). Standard online-softmax flash with GQA support and
optional sliding window. Grid: (batch, q_head, q_block, kv_block) with kv
minor-most — (m, l, acc) scratch accumulates across kv blocks. Causally-
skippable kv blocks are skipped with ``pl.when`` (block never contributes
compute); with a sliding window, out-of-window blocks are likewise skipped
— the triangle-skipping the blocked pure-jnp path cannot express (it masks
but still multiplies; see EXPERIMENTS.md §Perf).

``paged_flash_prefill_kernel`` — CHUNKED prefill against the shared page
pool (the unified-step hot path, DESIGN.md §6) with G-FOLD fetch
(DESIGN.md §8): Q is a contiguous (T, hd) chunk per request, K/V are
PHYSICAL pool pages gathered via the scalar-prefetched block table exactly
like the decode kernel (``paged_attention.py``). The grid is (B, KV, P) —
one step per KV head group, NOT per Q head — and the G query heads of the
group ride folded into one (G*T, hd) query tile (row g*T + t is head g,
chunk token t). Each physical K/V page is therefore DMA'd ONCE per KV-head
group and reused across all G query heads, cutting prefill HBM traffic by
~G× on GQA configs versus the per-Q-head fetch. This retires the PR 2
follow-up note; the old per-Q-head instantiation survives as
``paged_flash_prefill_kernel_per_qhead`` (the bit-parity oracle and the
before/after benchmark baseline — per-row dot/exp order is unchanged by
the fold, so outputs are bitwise identical).

Unmapped slots clamp to pool page 0 and are masked in-kernel off the same
scalar ref — a freed physical page may already hold ANOTHER request's live
tokens. Masking is by token position: kv pos <= q pos (+ optional window),
so intra-chunk causality falls out of write-then-attend; padding queries
(q_pos < 0) mask everything and emit zeros.

Fused score epilogue (``return_scores=True``, G-fold kernel only): per-
token K/V norms of each fetched page tile come out as byproduct outputs
kn/vn (B, KV, P, page), exactly as the decode kernel's epilogue
(DESIGN.md §8) — chunk-boundary eviction then reads the paper's Alg.1
page scores for free instead of re-walking the pool with ``block_score``.

Prefix sharing (DESIGN.md §7): an adopted page is a complete prompt-prefix
page whose positions are [slot*page, (slot+1)*page) for EVERY request
mapping it, and an adopting row's first chunk starts at q_pos ==
shared_tokens — so the kv-pos <= q-pos mask attends shared pages exactly as
if the row had prefilled them itself. No kernel change; the only retired
assumption is block-table-row disjointness, which neither kernel relied on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_attention import _pool_index

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  num_kv_blocks: int, block_q: int, block_k: int,
                  window: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal: kv block relevant iff k_start <= q_end; window: skip blocks
    # entirely below the window of every query row in the block
    relevant = k_start <= q_start + block_q - 1
    if window > 0:
        relevant &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[...].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > (qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, window: int = 0, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """Causal GQA flash attention.

    q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd).
    S must be a multiple of the block sizes (pad upstream).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    # layout: heads major so blocks are (block, hd) tiles
    qT = jnp.swapaxes(q, 1, 2)                              # (B, H, S, hd)
    kT = jnp.swapaxes(k, 1, 2)                              # (B, KV, S, hd)
    vT = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(_flash_kernel, num_kv_blocks=nk, block_q=bq,
                               block_k=bk, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, None, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qT, kT, vT)
    return jnp.swapaxes(out, 1, 2)


# ---------------------------------------------------------------------------
# paged chunked prefill (block-table indirection, scalar prefetch)
# ---------------------------------------------------------------------------

def _paged_prefill_kernel(bt_ref, q_ref, k_ref, v_ref, qpos_ref, kpos_ref,
                          *refs, num_pages: int, window: int, scale: float,
                          with_scores: bool):
    """One (batch, head-group, logical_page) step. Shared by the G-fold
    instantiation (rows = G*T query rows of one KV-head group) and the
    legacy per-Q-head one (rows = T) — the body only sees a (rows, hd)
    query tile; per-row masking makes the fold transparent.

    bt_ref   : (B, P) int32 block tables (scalar prefetch, SMEM)
    q_ref    : (rows, hd)  query tile
    k_ref    : (page, hd)  one PHYSICAL page of keys (block-table indexed)
    v_ref    : (page, hd)  one physical page of values
    qpos_ref : (1, rows)   per-row token positions (-1 == padding query)
    kpos_ref : (1, page)   token positions of that physical page (-1 invalid)
    outputs  : o (rows, hd) (written on the last page step); with_scores
               adds kn/vn (1, page) byproduct norm tiles
    scratch  : m (rows, 128), l (rows, 128), acc (rows, hd) f32
    """
    if with_scores:
        o_ref, kn_ref, vn_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                  # (rows, hd)
    k = k_ref[...].astype(jnp.float32)                  # (page, hd)
    v = v_ref[...].astype(jnp.float32)
    qpos = qpos_ref[0, :]                               # (rows,)
    kpos = kpos_ref[0, :]                               # (page,)
    mapped = bt_ref[b, p] >= 0

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # (rows, page): pool slot live AND causally visible from this query row
    valid = mapped & (kpos[None, :] >= 0) & (qpos[:, None] >= 0) & \
        (kpos[None, :] <= qpos[:, None])
    if window > 0:
        valid &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[:, 0:1]                              # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    pexp = jnp.where(valid, pexp, 0.0)
    l_new = alpha * l_scr[:, 0:1] + jnp.sum(pexp, axis=-1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc_new

    if with_scores:
        # byproduct epilogue (DESIGN.md §8): per-token norms of the K/V tile
        # already in VMEM; each (b, kv, p) block is written once per group
        kn_ref[0, :] = jnp.sqrt(jnp.sum(k * k, axis=-1))
        vn_ref[0, :] = jnp.sqrt(jnp.sum(v * v, axis=-1))

    @pl.when(p == num_pages - 1)
    def _finalize():
        # padding queries have l == 0 -> emit zeros, not NaN
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "interpret", "return_scores"))
def paged_flash_prefill_kernel(q, k_pool, v_pool, pos, block_table, q_pos, *,
                               window: int = 0, scale: float | None = None,
                               interpret: bool = True,
                               return_scores: bool = False):
    """Chunked-prefill attention over the shared page pool, G-fold fetch.

    q: (B, T, H, hd) — a contiguous chunk of queries per request (RoPE'd);
    k_pool/v_pool: (KV, N_pool, page, hd); pos: (N_pool, page) int32;
    block_table: (B, P) int32; q_pos: (B, T) int32 (-1 == padding)
    -> (B, T, H, hd) [, (kn, vn) each (B, KV, P, page) when
    ``return_scores``]. The chunk's own K/V must already be in the pool
    (write-then-attend).

    Grid is (B, KV, P): each physical K/V page is DMA'd once per KV-head
    GROUP; the group's G query heads are folded into one (G*T, hd) query
    tile (row g*T + t <-> head kv*G + g, token t) and reuse the tile —
    prefill HBM traffic is ~G× lower than the retired per-Q-head fetch
    (kept as :func:`paged_flash_prefill_kernel_per_qhead`, the bit-parity
    oracle). T == 1 callers should still use the decode kernel — its
    split-K walk shortens the serial chain (transformer dispatches so)."""
    B, T, H, hd = q.shape
    KV = k_pool.shape[0]
    G = H // KV
    page = k_pool.shape[2]
    P = block_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(_paged_prefill_kernel, num_pages=P,
                               window=window, scale=scale,
                               with_scores=return_scores)

    def kv_map(b, h, p, bt):
        return (h, _pool_index(bt, b, p), 0, 0)

    # fold heads: (B, T, H, hd) -> (B, H, T, hd) -> (B, KV, G*T, hd);
    # row g*T + t of group kv is (head kv*G + g, chunk token t)
    qf = jnp.swapaxes(q, 1, 2).reshape(B, KV, G * T, hd)
    qpos_f = jnp.tile(q_pos, (1, G))                        # (B, G*T)

    out_specs = [pl.BlockSpec((None, None, G * T, hd),
                              lambda b, h, p, bt: (b, h, 0, 0))]
    out_shapes = [jax.ShapeDtypeStruct((B, KV, G * T, hd), q.dtype)]
    if return_scores:
        norm = lambda b, h, p, bt: (b, h, p, 0)
        out_specs += [pl.BlockSpec((None, None, 1, page), norm),
                      pl.BlockSpec((None, None, 1, page), norm)]
        out_shapes += [jax.ShapeDtypeStruct((B, KV, P, page), jnp.float32),
                       jax.ShapeDtypeStruct((B, KV, P, page), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((None, None, G * T, hd),
                         lambda b, h, p, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((1, G * T), lambda b, h, p, bt: (b, 0)),
            pl.BlockSpec((1, page),
                         lambda b, h, p, bt: (_pool_index(bt, b, p), 0)),
        ],
        out_specs=tuple(out_specs),
        scratch_shapes=[
            pltpu.VMEM((G * T, 128), jnp.float32),
            pltpu.VMEM((G * T, 128), jnp.float32),
            pltpu.VMEM((G * T, hd), jnp.float32),
        ],
    )
    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(block_table, qf, k_pool, v_pool, qpos_f, pos)
    out = res[0]
    # unfold: (B, KV, G*T, hd) -> (B, KV, G, T, hd) -> (B, T, H, hd)
    out = jnp.swapaxes(out.reshape(B, KV * G, T, hd), 1, 2)
    if return_scores:
        return out, (res[1], res[2])
    return out


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def paged_flash_prefill_kernel_per_qhead(q, k_pool, v_pool, pos, block_table,
                                         q_pos, *, window: int = 0,
                                         scale: float | None = None,
                                         interpret: bool = True):
    """The retired per-Q-head instantiation — grid (B, H, P), each physical
    page DMA'd once per Q HEAD (G× the G-fold kernel's traffic on GQA).
    Kept as the bit-parity oracle for the fold (same kernel body, per-row
    math identical) and the before/after baseline in benchmarks/kernels.py.
    Signature/semantics match :func:`paged_flash_prefill_kernel`."""
    B, T, H, hd = q.shape
    KV = k_pool.shape[0]
    G = H // KV
    page = k_pool.shape[2]
    P = block_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(_paged_prefill_kernel, num_pages=P,
                               window=window, scale=scale, with_scores=False)

    def kv_map(b, h, p, bt):
        return (h // G, _pool_index(bt, b, p), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, P),
        in_specs=[
            pl.BlockSpec((None, None, T, hd), lambda b, h, p, bt: (b, h, 0, 0)),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((None, None, page, hd), kv_map),
            pl.BlockSpec((1, T), lambda b, h, p, bt: (b, 0)),
            pl.BlockSpec((1, page),
                         lambda b, h, p, bt: (_pool_index(bt, b, p), 0)),
        ],
        out_specs=pl.BlockSpec((None, None, T, hd),
                               lambda b, h, p, bt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T, 128), jnp.float32),
            pltpu.VMEM((T, 128), jnp.float32),
            pltpu.VMEM((T, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        interpret=interpret,
    )(block_table, jnp.swapaxes(q, 1, 2), k_pool, v_pool, q_pos, pos)
    return jnp.swapaxes(out, 1, 2)
