"""Pallas TPU causal flash-attention kernel (prefill hot path).

Standard online-softmax flash with GQA support and optional sliding
window. Grid: (batch, q_head, q_block, kv_block) with kv minor-most —
(m, l, acc) scratch accumulates across kv blocks. Causally-skippable kv
blocks are skipped with ``pl.when`` (block never contributes compute);
with a sliding window, out-of-window blocks are likewise skipped — this is
the triangle-skipping the blocked pure-jnp path cannot express (it masks
but still multiplies; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  num_kv_blocks: int, block_q: int, block_k: int,
                  window: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal: kv block relevant iff k_start <= q_end; window: skip blocks
    # entirely below the window of every query row in the block
    relevant = k_start <= q_start + block_q - 1
    if window > 0:
        relevant &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[...].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > (qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[:, 0:1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, window: int = 0, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """Causal GQA flash attention.

    q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd).
    S must be a multiple of the block sizes (pad upstream).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    # layout: heads major so blocks are (block, hd) tiles
    qT = jnp.swapaxes(q, 1, 2)                              # (B, H, S, hd)
    kT = jnp.swapaxes(k, 1, 2)                              # (B, KV, S, hd)
    vT = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(_flash_kernel, num_kv_blocks=nk, block_q=bq,
                               block_k=bk, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, None, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qT, kT, vT)
    return jnp.swapaxes(out, 1, 2)
