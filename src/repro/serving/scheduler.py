"""Chunk-aware continuous-batching scheduler (vLLM-style token budget).

Keeps a waiting queue and a fixed number of batch slots (the jitted unified
step has a static batch). Each engine iteration the scheduler emits ONE
:class:`StepPlan` mixing decode tokens and prompt chunks:

- **Admission**: a waiting request is admitted (FIFO) whenever a slot frees
  up. Because every policy statically bounds the per-request block table
  (budget + chunk headroom) and the pool is sized ``B * P``, admission can
  never over-commit HBM — no memory-pressure feedback loop, no preemption
  (DESIGN.md §2, §6).
- **Decode priority**: every RUNNING slot gets exactly 1 token first —
  decode latency (ITL) is never sacrificed to prefill throughput.
- **Prompt chunks**: the remaining ``token_budget`` is handed to PREFILLING
  slots in slot order, up to ``chunk_size`` tokens each, tracked via
  ``Request.prefill_pos``. A long prompt therefore spreads over many steps
  while decode rows keep emitting — the old engine's whole-prompt prefill
  stall is gone.

``token_budget`` floors at ``max_batch + 1`` so a prefilling request always
makes progress even with every other slot decoding.

**Prefix sharing** (DESIGN.md §7): when constructed with a ``page_size``,
the scheduler keeps a :class:`RadixPrefixIndex` — a page-granular trie over
the resident requests' prompt tokens. At admission it looks up the longest
FULL-page prefix the newcomer textually shares with a resident row, asks
the engine's device probe how much of that prefix actually survives in
every attention layer (eviction may have punched holes), and on a hit marks
the request to adopt those pages: its ``prefill_pos`` starts past the
shared tokens, so shared chunks are never recomputed, and the step's
``adopt`` entry tells the jitted step to remap + ref-bump the pages.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serving.request import Request, RequestStatus


class _RadixNode:
    __slots__ = ("children", "slots")

    def __init__(self):
        self.children: dict[bytes, _RadixNode] = {}
        self.slots: set[int] = set()


class RadixPrefixIndex:
    """Page-granular prefix trie over resident prompts (vLLM's automatic
    prefix caching, host side). Each edge is the raw bytes of one FULL page
    of prompt tokens — exact-match keys, so hash collisions cannot alias
    different prefixes. Only complete pages participate: a partially-filled
    page is the owner's write head and is never shareable."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _RadixNode()
        # slot -> [(parent, edge_key, node), ...] along its insertion path
        self._paths: dict[int, list[tuple[_RadixNode, bytes, _RadixNode]]] = {}

    def _keys(self, prompt: np.ndarray) -> list[bytes]:
        p = self.page_size
        n = len(prompt) // p
        arr = np.ascontiguousarray(np.asarray(prompt[:n * p], np.int32))
        return [arr[i * p:(i + 1) * p].tobytes() for i in range(n)]

    def insert(self, slot: int, prompt: np.ndarray) -> None:
        self.remove(slot)
        node, path = self.root, []
        for key in self._keys(prompt):
            child = node.children.setdefault(key, _RadixNode())
            child.slots.add(slot)
            path.append((node, key, child))
            node = child
        self._paths[slot] = path

    def remove(self, slot: int) -> None:
        for parent, key, node in reversed(self._paths.pop(slot, [])):
            node.slots.discard(slot)
            if not node.slots and not node.children:
                parent.children.pop(key, None)

    def lookup(self, prompt: np.ndarray,
               exclude: set[int] | None = None) -> tuple[int, int]:
        """Longest full-page prefix match -> (source_slot, n_pages);
        (-1, 0) when nothing matches. ``exclude``: slots whose device rows
        are stale this step (being reset) and must not serve as sources."""
        exclude = exclude or set()
        node, depth, best = self.root, 0, (-1, 0)
        for key in self._keys(prompt):
            child = node.children.get(key)
            if child is None:
                break
            cands = child.slots - exclude
            if not cands:
                break
            depth += 1
            best = (min(cands), depth)
            node = child
        return best


@dataclass
class StepPlan:
    """One unified step's worth of work.

    decode : (slot, request) rows feeding back their last sampled token
    prefill: (slot, request, chunk, completes) rows consuming ``chunk``
             prompt tokens; ``completes`` marks the prompt's final chunk
             (the step's sampled token is that request's FIRST output)
    reset  : slots whose row state must be wiped first (newly admitted —
             the previous occupant's pages return to the shared pool)
    adopt  : (slot, src_slot, n_pages) prefix-sharing adoptions riding the
             reset — slot maps src_slot's first n_pages prompt pages
    """
    decode: list[tuple[int, Request]] = field(default_factory=list)
    prefill: list[tuple[int, Request, np.ndarray, bool]] = \
        field(default_factory=list)
    reset: list[int] = field(default_factory=list)
    adopt: list[tuple[int, int, int]] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        return not self.decode and not self.prefill

    @property
    def num_tokens(self) -> int:
        return len(self.decode) + sum(len(c) for _, _, c, _ in self.prefill)


class Scheduler:
    def __init__(self, max_batch: int, chunk_size: int = 64,
                 token_budget: int | None = None,
                 page_size: int | None = None, prefix_probe=None):
        self.max_batch = max_batch
        self.chunk_size = chunk_size
        self.token_budget = max(token_budget or (max_batch + chunk_size),
                                max_batch + 1)
        self.waiting: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.finished: list[Request] = []
        # prefix sharing: index over resident prompts + the engine's device
        # probe (slot -> intact prefix pages). None == sharing disabled.
        self.prefix_index = RadixPrefixIndex(page_size) if page_size else None
        self.prefix_probe = prefix_probe
        # admission hook: called as on_admit(slot, req) the moment a request
        # is assigned a batch slot (the engine wires this to the per-request
        # timeline recorder; None == no observer)
        self.on_admit = None

    # ------------------------------------------------------------------ api
    def add(self, req: Request) -> None:
        req.status = RequestStatus.WAITING
        self.waiting.append(req)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def _match_prefix(self, req: Request, stale: set[int]) -> bool:
        """Host half of prefix-sharing admission: radix-match the prompt
        against resident rows, validate the hit against the engine's device
        probe, cap so at least one prompt token always prefills (the last
        token's logits seed the first output), and mark the request.

        Returns True to DEFER admission: the matched source is still
        prefilling the shared prefix, so the pages the newcomer would adopt
        don't exist yet — admitting now would forfeit the share and
        recompute the whole prompt (the batched-arrival case: N same-prefix
        requests land together, the first warms the pool for the rest)."""
        idx = self.prefix_index
        cap = (len(req.prompt) - 1) // idx.page_size
        src, n = idx.lookup(req.prompt, exclude=stale)
        if src < 0:
            # the only match (if any) is a slot admitted THIS call — its
            # pages don't exist on device yet; wait a step for them rather
            # than recompute the whole prefix
            src_any, n_any = idx.lookup(req.prompt)
            return src_any >= 0 and min(n_any, cap) > 0
        want = min(n, cap)
        have = want
        if self.prefix_probe is not None:
            have = min(want, int(self.prefix_probe(src)))
        if have < want:
            owner = self.slots[src]
            if owner is not None and owner.status == RequestStatus.PREFILLING:
                return True   # prefix still being written — wait for it
        if have > 0:
            req.share_src = src
            req.shared_tokens = have * idx.page_size
            req.prefill_pos = req.shared_tokens
        return False

    def schedule(self) -> list[tuple[int, Request]]:
        """Admit waiting requests into free slots (FIFO). Returns the newly
        admitted (slot, request) pairs — their first chunk is scheduled by
        the same step's :meth:`plan`."""
        admitted = []
        stale: set[int] = set()   # slots reset this step: device rows still
                                  # hold the PREVIOUS occupant's pages
        for slot in self.free_slots():
            if not self.waiting:
                break
            req = self.waiting[0]
            req.prefill_pos = 0
            req.share_src, req.shared_tokens = -1, 0
            if self.prefix_index is not None and \
                    self._match_prefix(req, stale):
                break         # FIFO: defer this request and those behind it
            self.waiting.popleft()
            req.slot = slot
            # admission stamp: queueing (incl. prefix-sharing deferral) ends
            # here; TTFT stays arrival-based, queue_time = this - arrival
            req.admission_time = time.perf_counter()
            req.status = RequestStatus.PREFILLING
            self.slots[slot] = req
            stale.add(slot)
            if self.prefix_index is not None:
                self.prefix_index.insert(slot, req.prompt)
            if self.on_admit is not None:
                self.on_admit(slot, req)
            admitted.append((slot, req))
        return admitted

    def plan(self) -> StepPlan:
        """Admit, then pack one unified step under the token budget."""
        admitted = self.schedule()
        plan = StepPlan(reset=[slot for slot, _ in admitted])
        page = self.prefix_index.page_size if self.prefix_index else 1
        plan.adopt = [(slot, r.share_src, r.shared_tokens // page)
                      for slot, r in admitted if r.share_src >= 0]
        plan.decode = self.active()
        budget = self.token_budget - len(plan.decode)
        for slot, req in self.prefilling():
            if budget <= 0:
                break
            n = min(self.chunk_size, req.prompt_remaining, budget)
            chunk = req.prompt[req.prefill_pos:req.prefill_pos + n]
            completes = req.prefill_pos + n >= len(req.prompt)
            plan.prefill.append((slot, req, chunk, completes))
            budget -= n
        return plan

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.status == RequestStatus.RUNNING]

    def prefilling(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.status == RequestStatus.PREFILLING]

    def retire(self, req: Request) -> None:
        assert req.finished
        if self.prefix_index is not None:
            self.prefix_index.remove(req.slot)
        self.slots[req.slot] = None
        req.slot = -1
        self.finished.append(req)

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0
