"""Chunk-aware continuous-batching scheduler (vLLM-style token budget).

Keeps a waiting queue and a fixed number of batch slots (the jitted unified
step has a static batch). Each engine iteration the scheduler emits ONE
:class:`StepPlan` mixing decode tokens and prompt chunks:

- **Admission**: a waiting request is admitted (FIFO) whenever a slot frees
  up. Because every policy statically bounds the per-request block table
  (budget + chunk headroom) and the pool is sized ``B * P``, admission can
  never over-commit HBM — no memory-pressure feedback loop, no preemption
  (DESIGN.md §2, §6).
- **Decode priority**: every RUNNING slot gets exactly 1 token first —
  decode latency (ITL) is never sacrificed to prefill throughput.
- **Prompt chunks**: the remaining ``token_budget`` is handed to PREFILLING
  slots in slot order, up to ``chunk_size`` tokens each, tracked via
  ``Request.prefill_pos``. A long prompt therefore spreads over many steps
  while decode rows keep emitting — the old engine's whole-prompt prefill
  stall is gone.

``token_budget`` floors at ``max_batch + 1`` so a prefilling request always
makes progress even with every other slot decoding.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serving.request import Request, RequestStatus


@dataclass
class StepPlan:
    """One unified step's worth of work.

    decode : (slot, request) rows feeding back their last sampled token
    prefill: (slot, request, chunk, completes) rows consuming ``chunk``
             prompt tokens; ``completes`` marks the prompt's final chunk
             (the step's sampled token is that request's FIRST output)
    reset  : slots whose row state must be wiped first (newly admitted —
             the previous occupant's pages return to the shared pool)
    """
    decode: list[tuple[int, Request]] = field(default_factory=list)
    prefill: list[tuple[int, Request, np.ndarray, bool]] = \
        field(default_factory=list)
    reset: list[int] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        return not self.decode and not self.prefill

    @property
    def num_tokens(self) -> int:
        return len(self.decode) + sum(len(c) for _, _, c, _ in self.prefill)


class Scheduler:
    def __init__(self, max_batch: int, chunk_size: int = 64,
                 token_budget: int | None = None):
        self.max_batch = max_batch
        self.chunk_size = chunk_size
        self.token_budget = max(token_budget or (max_batch + chunk_size),
                                max_batch + 1)
        self.waiting: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.finished: list[Request] = []

    # ------------------------------------------------------------------ api
    def add(self, req: Request) -> None:
        req.status = RequestStatus.WAITING
        self.waiting.append(req)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def schedule(self) -> list[tuple[int, Request]]:
        """Admit waiting requests into free slots (FIFO). Returns the newly
        admitted (slot, request) pairs — their first chunk is scheduled by
        the same step's :meth:`plan`."""
        admitted = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            req = self.waiting.popleft()
            req.slot = slot
            req.prefill_pos = 0
            req.status = RequestStatus.PREFILLING
            self.slots[slot] = req
            admitted.append((slot, req))
        return admitted

    def plan(self) -> StepPlan:
        """Admit, then pack one unified step under the token budget."""
        plan = StepPlan(reset=[slot for slot, _ in self.schedule()])
        plan.decode = self.active()
        budget = self.token_budget - len(plan.decode)
        for slot, req in self.prefilling():
            if budget <= 0:
                break
            n = min(self.chunk_size, req.prompt_remaining, budget)
            chunk = req.prompt[req.prefill_pos:req.prefill_pos + n]
            completes = req.prefill_pos + n >= len(req.prompt)
            plan.prefill.append((slot, req, chunk, completes))
            budget -= n
        return plan

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.status == RequestStatus.RUNNING]

    def prefilling(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.status == RequestStatus.PREFILLING]

    def retire(self, req: Request) -> None:
        assert req.finished
        self.slots[req.slot] = None
        req.slot = -1
        self.finished.append(req)

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0
