"""FIFO continuous-batching scheduler.

Keeps a waiting queue and a fixed number of batch slots (the jitted decode
step has a static batch). A waiting request is admitted whenever a slot
frees up; its prompt is prefilled into that slot's paged cache. This is
the vLLM scheduling shape minus preemption (the eviction policies bound
per-request cache statically, so admission can never over-commit memory —
a property vLLM has to enforce dynamically; see DESIGN.md §2).
"""
from __future__ import annotations

from collections import deque

from repro.serving.request import Request, RequestStatus


class Scheduler:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.waiting: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self.finished: list[Request] = []

    # ------------------------------------------------------------------ api
    def add(self, req: Request) -> None:
        req.status = RequestStatus.WAITING
        self.waiting.append(req)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def schedule(self) -> list[tuple[int, Request]]:
        """Admit waiting requests into free slots (FIFO). Returns the newly
        admitted (slot, request) pairs — the engine prefills these."""
        admitted = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            req = self.waiting.popleft()
            req.slot = slot
            req.status = RequestStatus.PREFILLING
            self.slots[slot] = req
            admitted.append((slot, req))
        return admitted

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.status == RequestStatus.RUNNING]

    def retire(self, req: Request) -> None:
        assert req.finished
        self.slots[req.slot] = None
        req.slot = -1
        self.finished.append(req)

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.num_active > 0
