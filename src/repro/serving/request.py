"""Request lifecycle objects for the serving engine."""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field

import numpy as np


class RequestStatus(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    RUNNING = "running"
    FINISHED_STOPPED = "finished_stopped"     # hit EOS
    FINISHED_LENGTH = "finished_length"       # hit max_new_tokens


@dataclass
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0            # 0 = no top-k
    top_p: float = 1.0        # 1.0 = no nucleus
    greedy: bool = True


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray                      # (S,) int32 token ids
    max_new_tokens: int = 64
    eos_token_id: int | None = None
    sampling: SamplingParams = field(default_factory=SamplingParams)

    status: RequestStatus = RequestStatus.WAITING
    output_tokens: list[int] = field(default_factory=list)
    slot: int = -1                          # engine batch slot while active
    prefill_pos: int = 0                    # prompt tokens already consumed
                                            # by chunked prefill
    share_src: int = -1                     # batch row whose prompt-prefix
                                            # pages this request adopted at
                                            # admission (-1 == none)
    shared_tokens: int = 0                  # prompt tokens covered by the
                                            # adopted pages (prefill skipped)
    arrival_time: float = field(default_factory=time.perf_counter)
    admission_time: float = 0.0             # perf_counter when the scheduler
                                            # assigned a batch slot (prefix-
                                            # sharing admissions may be
                                            # DEFERRED several steps past
                                            # arrival waiting for the shared
                                            # prefix to finish prefilling)
    first_token_time: float = 0.0           # perf_counter at first emission
    prefill_time: float = 0.0               # wall time spent in prefill steps
                                            # (adopters: only the NON-shared
                                            # chunks — adopted pages cost no
                                            # prefill compute)
    decode_times: list[float] = field(default_factory=list)
    probe: bool = True                      # eligible for eviction-regret
                                            # shadow probes (only sampled
                                            # when the engine runs with
                                            # ObsConfig.regret_every > 0)
    regret_samples: list[dict] = field(default_factory=list)
                                            # one dict per shadow probe:
                                            # per-layer divergence +
                                            # evicted attention mass
                                            # (obs/regret.py)

    def regret_summary(self) -> dict | None:
        """Aggregate this request's shadow-probe samples (None if never
        probed); see ``repro.obs.regret.summarize_request``."""
        from repro.obs.regret import summarize_request
        return summarize_request(self.regret_samples)

    @property
    def num_generated(self) -> int:
        return len(self.output_tokens)

    @property
    def prompt_remaining(self) -> int:
        return len(self.prompt) - self.prefill_pos

    @property
    def ttft(self) -> float:
        """Time-to-first-token (s); 0.0 until the first token is emitted.

        ALWAYS dated from ``arrival_time`` — the user-perceived latency.
        For a prefix-sharing adopter the prefill chunks are shorter (the
        adopted pages are skipped), but any queueing/deferral time between
        arrival and admission still counts: TTFT must never shrink just
        because the request waited for its prefix to become adoptable.
        ``queue_time`` exposes the waiting component separately."""
        if not self.first_token_time:
            return 0.0
        return self.first_token_time - self.arrival_time

    @property
    def queue_time(self) -> float:
        """Arrival -> slot assignment (s); 0.0 until admitted. Includes
        prefix-sharing deferral (waiting for the shared prefix's owner to
        finish prefilling it)."""
        if not self.admission_time:
            return 0.0
        return self.admission_time - self.arrival_time

    @property
    def finished(self) -> bool:
        return self.status in (RequestStatus.FINISHED_STOPPED,
                               RequestStatus.FINISHED_LENGTH)
