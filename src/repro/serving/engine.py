"""Continuous-batching serving engine (the vLLM-shaped runtime).

ONE unified step program (`models.transformer.forward_step`): each engine
iteration the scheduler packs up to ``token_budget`` tokens — one decode
token per RUNNING slot plus up to ``chunk_size`` prompt tokens per
PREFILLING slot — and a single jitted program appends them all straight
into the shared page pool, attends through block tables (paged
flash-prefill kernel on TPU), runs Alg.3 eviction on decode rows and
incremental Alg.2 compression at prefill chunk boundaries, and samples.
Decode-only iterations reuse the same function at T == 1, so a full mixed
workload compiles exactly two programs — there is no separate prefill
forward, no per-slot-specialized insert splice, and a long prompt never
stalls the decode slots sharing its batch (TTFT/ITL under mixed load is
what `benchmarks/latency.py` measures).

The eviction policy is a constructor argument — the paper's PagedEviction,
any baseline, or ``full``. Because every policy statically bounds the
per-request block table (budget + chunk headroom) and the pool is sized
for the full batch, admission can never over-commit HBM (DESIGN.md §2,
§6); pages a request evicts — or releases when it retires — return to the
SHARED free list and become headroom for every other request.

Telemetry (DESIGN.md §9): the engine is instrumented end to end through
``repro.obs``. Each step, pool-event counts (pages allocated / freed /
evicted / forked / adopted, tokens written / evicted, force-evicts) ride
OUT of the jitted program as a tiny int32 stats vector accumulated by the
``paged_cache`` mutators themselves — no host callbacks on the hot path —
and are reconciled into a host :class:`~repro.obs.MetricsRegistry`
(latency histograms with real p50/p90/p99 for TTFT, ITL, TPOT, step wall
time, scheduler plan time; counters; gauges). Optionally every iteration
emits one JSONL trace event (step kind, batch mix, tokens, page counters,
pool occupancy, program-cache size) through a buffered
:class:`~repro.obs.TraceWriter`. A recompile sentinel tracks the
compiled-program count against the known ceiling (2: T == chunk and
T == 1) and flags any unexpected compile once through the trace. The
legacy :class:`EngineStats` scalars and :meth:`Engine.pool_stats`
(fleet-level pool occupancy, host-recomputed from ref counts) remain the
benchmark-facing summaries; ``BENCH_obs.json`` gates the fully
instrumented TPOT ladder at ≤2% overhead vs. instrumentation off.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CacheConfig, ModelConfig
from repro.core import devstats
from repro.core.paged_cache import lineage_snapshot
from repro.core.policies import EvictionPolicy, get_policy
from repro.models.transformer import (
    ModelCache,
    collect_step_stats,
    forward_step,
    init_decode_caches,
    intact_prefix_pages,
)
from repro.obs import EngineObs, ObsConfig
from repro.obs.lineage import StepPlanContext
from repro.obs.regret import (REGRET_BOUNDS, ShadowState, probe_record,
                              run_probe)
from repro.obs.trace import TRACE_SCHEMA_VERSION, annotation
from repro.serving.request import Request, RequestStatus, SamplingParams
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import Scheduler


@dataclass
class EngineStats:
    steps: int = 0               # every unified step (mixed + decode-only)
    decode_steps: int = 0        # decode-only steps — the ones whose wall
                                 # time lands in decode_s
    tokens_generated: int = 0    # every emitted token (mixed steps included)
    decode_tokens: int = 0       # tokens from decode-only steps
    pages_evicted: int = 0
    tokens_evicted: int = 0
    forced_evictions: int = 0
    shared_prefix_hits: int = 0   # admissions that adopted resident pages
    shared_prefix_tokens: int = 0  # prompt tokens whose prefill was skipped
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, cache_cfg: CacheConfig,
                 max_batch: int = 8, max_prompt_len: int = 256,
                 max_new_tokens: int = 128, sampling: SamplingParams | None = None,
                 use_pallas: bool = False, seed: int = 0,
                 chunk_size: int = 64, token_budget: int | None = None,
                 prefix_sharing: bool = True, decode_splits: int = 1,
                 fused_scores: bool | None = None,
                 obs: ObsConfig | None = None, tp: int = 1, mesh=None):
        self.cfg = cfg
        self.params = params
        self.ccfg = cache_cfg
        # tensor parallelism (DESIGN.md §11): tp > 1 serves the unified step
        # shard_map'd over a (1, tp) device mesh — KV-head-sharded pool and
        # kernels, replicated metadata/scheduler. tp == 1 is the unchanged
        # single-device path (no mesh, no shard_map, bit-identical HLO).
        self.tp = tp
        self._tp_axis = "model" if tp > 1 else None
        if tp > 1:
            from repro.sharding import rules as _rules
            _rules.validate_tp(cfg, tp)
        self.mesh = mesh
        self.policy: EvictionPolicy = get_policy(cache_cfg.policy,
                                                 tp_axis=self._tp_axis)
        self.max_batch = max_batch
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.total_len = max_prompt_len + max_new_tokens
        self.sampling = sampling or SamplingParams()
        self.use_pallas = use_pallas
        # split-K decode (DESIGN.md §8): partition the page walk of the
        # Pallas decode kernel; 1 == off. Fused eviction scores default to
        # riding along whenever the Pallas kernels run (they emit the score
        # epilogue for free); pass False to force the stored-score path.
        self.decode_splits = decode_splits
        self.fused_scores = use_pallas if fused_scores is None else fused_scores
        self.chunk_size = min(chunk_size, max_prompt_len)
        # prefix sharing needs every layer's prompt state to live in paged
        # KV: recurrent mixers (mamba/xLSTM) and cross-attention state can't
        # be adopted page-wise, so sharing stays off for those archs
        self._sharing_ok = (prefix_sharing
                            and all(s.mixer == "attn"
                                    for s in cfg.layer_pattern())
                            and not cfg.cross_attention)
        self.scheduler = Scheduler(
            max_batch, chunk_size=self.chunk_size, token_budget=token_budget,
            page_size=cache_cfg.page_size if self._sharing_ok else None,
            prefix_probe=self._prefix_probe if self._sharing_ok else None)
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self._next_id = 0

        # telemetry (DESIGN.md §9): metrics default ON — the device stats
        # vector + registry are the ≤2%-overhead path BENCH_obs.json gates.
        # obs=ObsConfig(metrics=False) restores the bare pre-obs pytree.
        self.obs = EngineObs(obs if obs is not None else ObsConfig())
        self._t_start = time.perf_counter()
        self._programs_seen = 0
        self._warned_compile = False
        # forensics (DESIGN.md §10): per-request timeline hooks, lineage
        # snapshot function, and the regret shadow cache. ``_want_taps`` is
        # python-static — False compiles the exact pre-forensics program.
        self._want_taps = self.obs.cfg.regret_every > 0
        if self._want_taps and tp > 1:
            raise ValueError("regret shadow probes are not supported under "
                             "tensor parallelism (tp > 1): the tap pytree "
                             "would need per-shard out_specs; probe at tp=1")
        self._shadow: ShadowState | None = None
        if self.obs.timeline is not None:
            self.scheduler.on_admit = self._on_admit

        # batch-wide state (block tables carry chunk headroom: a prefilling
        # row transiently holds budget + chunk tokens between boundaries)
        self.cache: ModelCache = init_decode_caches(
            cfg, max_batch, self.total_len, self.policy, self.ccfg,
            chunk_tokens=self.chunk_size, track_stats=self.obs.cfg.metrics)
        self.cur_tokens = np.zeros((max_batch,), np.int32)

        # running pool occupancy, maintained from the device stats deltas
        # (Δfree == freed - allocated) so per-step trace events never pay a
        # pool_stats() device_get. Initial state is static: each attention
        # layer starts with `batch` pre-mapped working pages.
        total = free = 0
        for lc in list(self.cache.pattern) + list(self.cache.tail):
            if lc.kv is None:
                continue
            shp = lc.kv.ref_count.shape        # (R, N) stacked or (N,) tail
            reps, n = (shp if len(shp) == 2 else (1, shp[0]))
            total += reps * n
            free += reps * (n - max_batch)
        self._pool_pages_total = total
        self._free_pages_est = free

        if tp > 1:
            self._init_tp()
        else:
            self._step_fn = jax.jit(self._step_impl)
        self._probe_fn = jax.jit(intact_prefix_pages)
        # lineage ledger: one jitted gather of the FIRST attention layer's
        # pool view per step (block table, ref counts, per-page tokens /
        # base positions / policy scores)
        self._lineage_fn = (jax.jit(self._lineage_impl)
                            if self.obs.ledger is not None else None)

    def _init_tp(self) -> None:
        """Build the tensor-parallel step: place params/cache with their
        manual shardings and wrap ``_step_impl`` in shard_map over the
        (1, tp) mesh (DESIGN.md §11). Everything host-side — the scheduler,
        radix prefix index, free-list estimate, lineage ledger — keeps
        reading the replicated metadata leaves exactly as at tp=1."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.launch.mesh import make_tp_mesh
        from repro.models.moe import _shard_map
        from repro.sharding import rules

        if self.mesh is None:
            self.mesh = make_tp_mesh(self.tp)
        mesh = self.mesh
        p_specs = rules.tp_param_specs(self.params)
        c_specs = rules.tp_cache_specs(self.cache)
        self.params = jax.device_put(
            self.params, rules.tp_param_shardings(mesh, self.params))
        self.cache = jax.device_put(
            self.cache, rules.tp_cache_shardings(mesh, self.cache))
        rep = P()
        in_specs = (p_specs, rep, rep, rep, rep, rep, rep, rep, c_specs, rep)
        # outputs: (next_tok replicated, cache, stats replicated-or-None,
        # taps always None under TP — gated in __init__)
        stats_spec = rep if self.obs.cfg.metrics else None
        out_specs = (rep, c_specs, stats_spec, None)
        self._step_fn = jax.jit(_shard_map(
            self._step_impl, mesh, in_specs=in_specs, out_specs=out_specs,
            manual_axes=("data", "model")))

    @staticmethod
    def _lineage_impl(cache: ModelCache):
        for lc in cache.pattern:
            if lc.kv is not None:
                # stacked pattern slots: rep 0 is the first attention layer
                return lineage_snapshot(
                    jax.tree.map(lambda a: a[0], lc.kv))
        for lc in cache.tail:
            if lc.kv is not None:
                return lineage_snapshot(lc.kv)
        return None

    # ---------------------------------------------------------------- jitted
    def _step_impl(self, params, tokens, n_tok, decode_mask, prefill_mask,
                   reset_mask, share_src, share_pages, cache, key):
        """The unified step: append + attend + evict + sample. Compiled once
        per token-dim T — the engine only ever calls it with T == chunk_size
        (mixed/prefill steps) and T == 1 (decode-only steps).

        Third output: the summed device stats vector ((devstats.NSTATS,)
        int32, this step's pool events across every attention layer), or
        None when the caches don't track stats — summing happens INSIDE the
        jit so telemetry costs one reduction + one tiny transfer, never a
        host callback.

        Fourth output: the regret-probe taps (per-attention-layer k/v/q/o +
        live positions; obs/regret.py), or None when probes are off —
        ``_want_taps`` is static, so the probes-off program is bit-identical
        to the never-instrumented one."""
        out = forward_step(
            params, self.cfg, tokens, n_tok, cache, self.policy, self.ccfg,
            decode_mask=decode_mask, prefill_mask=prefill_mask,
            reset_mask=reset_mask, share_src=share_src,
            share_pages=share_pages, use_pallas=self.use_pallas,
            decode_splits=self.decode_splits, fused_scores=self.fused_scores,
            want_taps=self._want_taps, tp_axis=self._tp_axis)
        logits, cache = out[0], out[1]
        taps = out[2] if self._want_taps else None
        s = self.sampling
        next_tok = sample_tokens(key, logits, temperature=s.temperature,
                                 top_k=s.top_k, top_p=s.top_p, greedy=s.greedy)
        st = collect_step_stats(cache)
        if st is not None and self._tp_axis is not None:
            # sharding-aware devstats: metadata mutations run replicated on
            # every shard, so a plain sum over the mesh would count each
            # pool event tp times and break PR 8's conservation identities.
            # Keep shard 0's vector and psum — a true mesh collective whose
            # result still reconciles EXACTLY with host pool accounting.
            idx = jax.lax.axis_index(self._tp_axis)
            st = jax.lax.psum(jnp.where(idx == 0, st, 0), self._tp_axis)
        return next_tok, cache, st, taps

    def _prefix_probe(self, slot: int) -> int:
        """Device half of prefix-sharing admission (scheduler callback):
        how many leading full prompt pages of batch row ``slot`` survive
        intact in every attention layer."""
        return int(self._probe_fn(self.cache, jnp.int32(slot)))

    # ------------------------------------------------------------------- api
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int | None = None,
               eos_token_id: int | None = None) -> Request:
        assert 0 < len(prompt) <= self.max_prompt_len, (
            f"prompt len {len(prompt)} not in (0, {self.max_prompt_len}]")
        req = Request(request_id=self._next_id,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens or self.max_new_tokens,
                      eos_token_id=eos_token_id)
        self._next_id += 1
        self.scheduler.add(req)
        if self.obs.timeline is not None:
            self.obs.timeline.request_submitted(req.request_id,
                                                time.perf_counter())
        return req

    def _on_admit(self, slot: int, req: Request) -> None:
        """Scheduler admission hook → timeline (queue span ends here)."""
        self.obs.timeline.request_admitted(
            req.request_id, req.admission_time, slot=slot,
            shared_tokens=req.shared_tokens,
            shared_pages=(req.shared_tokens // self.ccfg.page_size
                          if req.shared_tokens else 0),
            prompt_tokens=len(req.prompt))

    def _maybe_finish(self, req: Request) -> None:
        last = req.output_tokens[-1] if req.output_tokens else None
        if req.eos_token_id is not None and last == req.eos_token_id:
            req.status = RequestStatus.FINISHED_STOPPED
        elif req.num_generated >= req.max_new_tokens:
            req.status = RequestStatus.FINISHED_LENGTH
        if req.finished:
            if self.obs.timeline is not None:
                self.obs.timeline.request_finished(
                    req.request_id, time.perf_counter(),
                    tokens=req.num_generated, reason=req.status.value)
            self.scheduler.retire(req)
            if self.obs.cfg.metrics:
                reg = self.obs.registry
                reg.counter("engine.requests_finished").inc()
                if req.decode_times:
                    reg.histogram("engine.tpot_s").observe(
                        sum(req.decode_times) / len(req.decode_times))

    # ------------------------------------------------------------- telemetry
    def _check_recompile(self) -> bool:
        """Recompile sentinel: returns True iff this step grew the compiled-
        program cache PAST the known ceiling (2 programs: T == chunk and
        T == 1). The first unexpected compile warns once; every one bumps
        the counter and flags the step's trace event."""
        n = self.num_compiled_programs()
        if n < 0:                         # no _cache_size introspection
            return False
        grew, self._programs_seen = n > self._programs_seen, n
        unexpected = grew and n > self.obs.cfg.program_ceiling
        if self.obs.cfg.metrics:
            self.obs.registry.gauge("engine.programs").set(n)
            if unexpected:
                self.obs.registry.counter("engine.unexpected_compiles").inc()
        if unexpected and not self._warned_compile:
            self._warned_compile = True
            warnings.warn(
                f"engine step compiled program #{n} (ceiling "
                f"{self.obs.cfg.program_ceiling}) — an operand shape or "
                f"static argument is varying across steps", stacklevel=3)
        return unexpected

    def _emit_trace(self, kind: str, plan, plan_dt: float, step_dt: float,
                    tokens: int, st, finished: int, unexpected: bool) -> None:
        ev = {
            "v": TRACE_SCHEMA_VERSION,
            "rec": "step",
            "step": self.stats.steps,
            "kind": kind,
            "t_ms": (time.perf_counter() - self._t_start) * 1e3,
            "plan_ms": plan_dt * 1e3,
            "step_ms": step_dt * 1e3,
            "decode_rows": len(plan.decode),
            "prefill_rows": len(plan.prefill),
            "reset_rows": len(plan.reset),
            "adopt_rows": len(plan.adopt),
            "tokens": tokens,
            "programs": max(self._programs_seen, 0),
            "finished": finished,
        }
        if st is not None:
            for i, name in enumerate(devstats.STAT_NAMES):
                ev[name] = int(st[i])
            ev["pool_pages"] = self._pool_pages_total
            ev["free_pages"] = self._free_pages_est
        if unexpected:
            ev["unexpected_compile"] = True
        self.obs.writer.emit(ev)

    def step(self) -> bool:
        """One engine iteration: plan a unified step (admission + decode
        tokens + prompt chunks) and run it. Returns whether work remains."""
        oc = self.obs.cfg
        t_plan0 = time.perf_counter()
        with annotation("engine.plan", enabled=oc.profiler_annotations):
            plan = self.scheduler.plan()
        plan_dt = time.perf_counter() - t_plan0
        if oc.metrics:
            self.obs.registry.histogram("engine.plan_s").observe(plan_dt)
        if plan.empty:
            if self.obs.writer is not None:
                self._emit_trace("idle", plan, plan_dt, 0.0, 0, None, 0, False)
            return self.scheduler.has_work()
        B = self.max_batch
        T = self.chunk_size if plan.prefill else 1
        tokens = np.zeros((B, T), np.int32)
        n_tok = np.zeros((B,), np.int32)
        decode_mask = np.zeros((B,), bool)
        prefill_mask = np.zeros((B,), bool)
        reset_mask = np.zeros((B,), bool)
        reset_mask[plan.reset] = True
        share_src = np.full((B,), -1, np.int32)
        share_pages = np.zeros((B,), np.int32)
        for slot, src, n_pages in plan.adopt:
            share_src[slot] = src
            share_pages[slot] = n_pages
            self.stats.shared_prefix_hits += 1
            self.stats.shared_prefix_tokens += n_pages * self.ccfg.page_size
        for slot, req in plan.decode:
            tokens[slot, 0] = self.cur_tokens[slot]
            n_tok[slot] = 1
            decode_mask[slot] = True
        for slot, req, chunk, _ in plan.prefill:
            tokens[slot, :len(chunk)] = chunk
            n_tok[slot] = len(chunk)
            prefill_mask[slot] = True
            req.prefill_pos += len(chunk)

        t0 = time.perf_counter()
        self._key, sk = jax.random.split(self._key)
        with annotation("engine.step", enabled=oc.profiler_annotations):
            next_tok, self.cache, stats_dev, taps = self._step_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(n_tok),
                jnp.asarray(decode_mask), jnp.asarray(prefill_mask),
                jnp.asarray(reset_mask), jnp.asarray(share_src),
                jnp.asarray(share_pages), self.cache, sk)
            next_np = np.asarray(jax.device_get(next_tok))
        dt = time.perf_counter() - t0
        now = time.perf_counter()
        unexpected = self._check_recompile()
        self.stats.steps += 1
        if plan.prefill:
            self.stats.prefill_s += dt
        else:
            self.stats.decode_s += dt
            self.stats.decode_steps += 1

        # reconcile this step's device pool events (one (NSTATS,) transfer)
        st = None
        if stats_dev is not None:
            st = np.asarray(jax.device_get(stats_dev))
            self.stats.pages_evicted += int(st[devstats.PAGES_EVICTED])
            self.stats.tokens_evicted += int(st[devstats.TOKENS_EVICTED])
            self.stats.forced_evictions += int(st[devstats.FORCED_EVICTIONS])
            self._free_pages_est += int(st[devstats.PAGES_FREED]) - \
                int(st[devstats.PAGES_ALLOCATED])

        # forensics (DESIGN.md §10) — all host-side, plan-contextualized.
        # Runs BEFORE the finish loops below so slot -> request attribution
        # still sees this step's owners.
        step_no = self.stats.steps
        lin_events = []
        if self.obs.ledger is not None:
            snap = jax.device_get(self._lineage_fn(self.cache))
            ctx = StepPlanContext(
                reset_slots=frozenset(plan.reset),
                adopt={slot: (src, n_pages)
                       for slot, src, n_pages in plan.adopt})
            lin_events = self.obs.ledger.observe_step(step_no, snap, ctx)
            if self.obs.writer is not None:
                for evn in lin_events:
                    self.obs.writer.emit(evn.to_record())
        if taps is not None:
            self._observe_regret(plan, taps, n_tok, step_no)
        tl = self.obs.timeline
        if tl is not None:
            kind_tl = "mixed" if (plan.prefill and plan.decode) else (
                "prefill" if plan.prefill else "decode")
            tl.engine_step(step_no, kind_tl, t0, dt,
                           tokens=int(n_tok.sum()))
            for slot, req in plan.decode:
                tl.decode_step(req.request_id, t0)
            for slot, req, chunk, _ in plan.prefill:
                tl.prefill_chunk(req.request_id, t0, t0 + dt,
                                 tokens=len(chunk), step=step_no)
            if st is not None and int(st[devstats.PAGES_EVICTED]) > 0:
                tl.engine_instant(now, "pages_evicted",
                                  count=int(st[devstats.PAGES_EVICTED]))
            for evn in lin_events:
                if evn.etype == "evict":
                    owner = self.scheduler.slots[evn.slot]
                    if owner is not None:
                        tl.request_evicted_page(owner.request_id, now,
                                                page=evn.page, lpi=evn.lpi,
                                                score=evn.score)

        reg = self.obs.registry if oc.metrics else None
        if reg is not None:
            reg.histogram("engine.step_wall_s").observe(dt)
            reg.counter("engine.steps").inc()
            reg.counter("engine.tokens").inc(int(n_tok.sum()))
            if st is not None:
                for i, name in enumerate(devstats.STAT_NAMES):
                    reg.counter(f"pool.{name}").inc(int(st[i]))
                reg.gauge("pool.free_pages").set(self._free_pages_est)
                reg.gauge("pool.total_pages").set(self._pool_pages_total)
            for slot in plan.reset:
                r = self.scheduler.slots[slot]
                if r is not None:
                    reg.histogram("engine.queue_s").observe(r.queue_time)

        finished_before = len(self.scheduler.finished)
        for slot, req in plan.decode:
            req.output_tokens.append(int(next_np[slot]))
            req.decode_times.append(dt)
            self.cur_tokens[slot] = next_np[slot]
            self.stats.tokens_generated += 1
            if not plan.prefill:
                self.stats.decode_tokens += 1
            if reg is not None:
                reg.histogram("engine.itl_s").observe(dt)
            self._maybe_finish(req)
        for slot, req, chunk, completes in plan.prefill:
            req.prefill_time += dt
            if completes:
                # the sampled token at the prompt's last position is this
                # request's FIRST output token (its TTFT moment, dated from
                # ARRIVAL — an adopter's shorter prefill must not hide its
                # queueing/deferral time; see Request.ttft)
                req.output_tokens.append(int(next_np[slot]))
                req.first_token_time = now
                self.cur_tokens[slot] = next_np[slot]
                req.status = RequestStatus.RUNNING
                self.stats.tokens_generated += 1
                if reg is not None:
                    reg.histogram("engine.ttft_s").observe(
                        now - req.arrival_time)
                self._maybe_finish(req)
        if self.obs.writer is not None:
            kind = "mixed" if (plan.prefill and plan.decode) else \
                ("prefill" if plan.prefill else "decode")
            self._emit_trace(kind, plan, plan_dt, dt, int(n_tok.sum()), st,
                             len(self.scheduler.finished) - finished_before,
                             unexpected)
        return self.scheduler.has_work()

    def _observe_regret(self, plan, taps, n_tok, step_no: int) -> None:
        """Shadow-probe bookkeeping (obs/regret.py): device taps → host
        shadow history mirroring the pool's lifecycle, then a sampled
        full-cache recompute on this step's flagged decode rows."""
        taps = jax.device_get(taps)
        layers = []
        for tp in taps["pattern"]:
            if tp is None:
                continue
            reps = tp["k"].shape[0]        # stacked over pattern repetitions
            for r in range(reps):
                layers.append({k: v[r] for k, v in tp.items()})
        layers += [tp for tp in taps["tail"] if tp is not None]
        if not layers:
            return
        positions = np.asarray(taps["positions"])
        if self._shadow is None:
            KV, hd = layers[0]["k"].shape[-2:]
            self._shadow = ShadowState(len(layers), self.max_batch,
                                       self.total_len, KV, hd)
        sh = self._shadow
        for slot in plan.reset:
            sh.reset_row(slot)
        for slot, src, n_pages in plan.adopt:
            sh.adopt(slot, src, n_pages * self.ccfg.page_size)
        sh.record_step(layers, positions, n_tok)
        every = self.obs.cfg.regret_every
        rows, by_slot = [], {}
        for slot, req in plan.decode:
            if req.probe and len(req.decode_times) % every == 0:
                rows.append(slot)
                by_slot[slot] = req
        if not rows:
            return
        reg = self.obs.registry if self.obs.cfg.metrics else None
        for s in run_probe(sh, layers, positions, n_tok, rows):
            req = by_slot[s["slot"]]
            req.regret_samples.append(s)
            if self.obs.writer is not None:
                self.obs.writer.emit(probe_record(
                    s, step=step_no, request_id=req.request_id))
            if reg is not None:
                reg.histogram("engine.eviction_regret",
                              bounds=REGRET_BOUNDS).observe(
                                  float(np.mean(s["divergence"])))
                reg.histogram("engine.evicted_attention_mass",
                              bounds=REGRET_BOUNDS).observe(
                                  float(np.mean(s["evicted_mass"])))

    def shadow_nbytes(self) -> int:
        """Host bytes held by the regret shadow cache (0 when probes off)."""
        return self._shadow.nbytes() if self._shadow is not None else 0

    def run(self, max_steps: int = 100_000) -> list[Request]:
        """Drive :meth:`step` to completion. Crash safety: an exception
        anywhere in the loop flushes the buffered trace tail before
        propagating, so the trace ends at the failing step — plus the
        writer's own atexit fallback for exits that bypass this frame."""
        steps = 0
        try:
            while self.step() and steps < max_steps:
                steps += 1
        except BaseException:
            if self.obs.writer is not None:
                self.obs.writer.flush()
            raise
        return self.scheduler.finished

    def num_compiled_programs(self) -> int:
        """Distinct compiled executables behind the engine (the per-slot
        recompilation family is dead: expect 2 — T == chunk and T == 1).
        The recompile sentinel mirrors this into the ``engine.programs``
        gauge and counts ceiling crossings in ``engine.unexpected_compiles``."""
        size = getattr(self._step_fn, "_cache_size", None)
        return int(size()) if callable(size) else -1

    def metrics_snapshot(self) -> dict:
        """JSON-safe snapshot of every metric (see MetricsRegistry)."""
        return self.obs.registry.snapshot()

    def close(self) -> None:
        """Flush and close the trace writer (idempotent)."""
        self.obs.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def export_timeline(self, path: str) -> int:
        """Write the per-request Perfetto/Chrome-trace timeline; returns the
        event count. Requires ``ObsConfig(timeline=True)``."""
        if self.obs.timeline is None:
            raise ValueError("engine was not run with ObsConfig(timeline=True)")
        return self.obs.timeline.export(path)

    def pool_stats(self) -> dict:
        """Fleet-level page-pool occupancy, aggregated over attention layers:
        total physical pages, pages on the free list, utilization, and the
        prefix-sharing telemetry — pages mapped by more than one block table
        and the physical pages sharing saves (sum of ref_count - 1)."""
        total = free = shared = extra = 0
        for lc in list(self.cache.pattern) + list(self.cache.tail):
            if lc.kv is None:
                continue
            ref = np.asarray(jax.device_get(lc.kv.ref_count)).reshape(-1)
            total += ref.size
            free += int((ref == 0).sum())
            shared += int((ref > 1).sum())
            extra += int((ref[ref > 1] - 1).sum())
        return {"pool_pages": total, "free_pages": free,
                "utilization": (total - free) / total if total else 0.0,
                "shared_pages": shared, "pages_saved_by_sharing": extra}

    def pool_bytes(self) -> dict:
        """HBM accounting for the page-pool PAYLOAD (K/V tensors + int8
        scales — the bytes that scale with budget, and the bytes TP divides;
        pool metadata is replicated by design and reported separately).
        ``per_device_max`` is measured from the real array shards, so the
        benchmark gate ``per_device_max <= total/tp + page`` checks what the
        runtime actually holds, not what the specs promise."""
        total = meta = 0
        per_dev: dict[int, int] = {}
        for lc in list(self.cache.pattern) + list(self.cache.tail):
            if lc.kv is None:
                continue
            kv = lc.kv
            for leaf in (kv.k, kv.v, kv.k_scale, kv.v_scale):
                if leaf is None:
                    continue
                total += leaf.nbytes
                for sh in leaf.addressable_shards:
                    d = sh.device.id
                    per_dev[d] = per_dev.get(d, 0) + sh.data.nbytes
            for leaf in (kv.pos, kv.score, kv.block_table, kv.ref_count,
                         kv.cur_page, kv.cur_off, kv.stats):
                if leaf is not None:
                    meta += leaf.nbytes
        return {"payload_total": total,
                "per_device_max": max(per_dev.values()) if per_dev else 0,
                "metadata_total": meta,
                "devices": len(per_dev)}
