"""Continuous-batching serving engine (the vLLM-shaped runtime).

Three compiled programs:
  prefill : batch-1 prompt (padded to ``max_prompt_len``) -> per-slot cache
  insert  : splice a prefilled single-request cache into the batch cache —
            with the shared page pool this frees the leaving request's
            pages, allocates fresh ones from the free list, and rewrites
            ONE block-table row (O(P) page copies, no slab transfer)
  decode  : one token for every active slot (static batch) + sampling

The eviction policy is a constructor argument — the paper's PagedEviction,
any baseline, or ``full``. Because every policy statically bounds the
per-request block table and the pool is sized for the full batch,
admission can never over-commit HBM (DESIGN.md §2); pages a request evicts
return to the SHARED free list and become headroom for every other request.

Telemetry per step: pages/tokens evicted, forced (fragmentation) evictions,
wall time — the benchmarks build the paper's throughput/TPOT/overhead
tables from these. :meth:`Engine.pool_stats` reports fleet-level pool
occupancy (free vs mapped physical pages across layers).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CacheConfig, ModelConfig
from repro.core.policies import EvictionPolicy, get_policy
from repro.models.transformer import (
    ModelCache,
    decode_step,
    forward_prefill,
    init_decode_caches,
    insert_request_cache,
)
from repro.serving.request import Request, RequestStatus, SamplingParams
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import Scheduler


@dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    pages_evicted: int = 0
    tokens_evicted: int = 0
    forced_evictions: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_generated / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, cache_cfg: CacheConfig,
                 max_batch: int = 8, max_prompt_len: int = 256,
                 max_new_tokens: int = 128, sampling: SamplingParams | None = None,
                 use_pallas: bool = False, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ccfg = cache_cfg
        self.policy: EvictionPolicy = get_policy(cache_cfg.policy)
        self.max_batch = max_batch
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.total_len = max_prompt_len + max_new_tokens
        self.sampling = sampling or SamplingParams()
        self.use_pallas = use_pallas
        self.scheduler = Scheduler(max_batch)
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self._next_id = 0

        # batch-wide state
        self.cache: ModelCache = init_decode_caches(
            cfg, max_batch, self.total_len, self.policy, self.ccfg)
        self.cur_tokens = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), bool)

        self._prefill_fn = jax.jit(self._prefill_impl)
        self._insert_fn = jax.jit(self._insert_impl, static_argnames=("slot",))
        self._decode_fn = jax.jit(self._decode_impl)

    # ---------------------------------------------------------------- jitted
    def _prefill_impl(self, params, tokens, valid):
        return forward_prefill(params, self.cfg, tokens, self.policy,
                               self.ccfg, valid=valid,
                               total_seq_hint=self.total_len,
                               use_pallas=self.use_pallas)

    def _insert_impl(self, batch_cache, single_cache, *, slot: int):
        # paged KV leaves splice through the shared pool's block tables;
        # recurrent / cross-attn states are plain batch-row writes
        return insert_request_cache(batch_cache, single_cache, slot)

    def _decode_impl(self, params, tokens, cache, active, key):
        logits, cache = decode_step(params, self.cfg, tokens, cache,
                                    self.policy, self.ccfg, active=active,
                                    use_pallas=self.use_pallas)
        s = self.sampling
        next_tok = sample_tokens(key, logits, temperature=s.temperature,
                                 top_k=s.top_k, top_p=s.top_p, greedy=s.greedy)
        return next_tok, cache

    # ------------------------------------------------------------------- api
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int | None = None,
               eos_token_id: int | None = None) -> Request:
        assert len(prompt) <= self.max_prompt_len, (
            f"prompt len {len(prompt)} > max_prompt_len {self.max_prompt_len}")
        req = Request(request_id=self._next_id,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens or self.max_new_tokens,
                      eos_token_id=eos_token_id)
        self._next_id += 1
        self.scheduler.add(req)
        return req

    def _admit(self) -> None:
        for slot, req in self.scheduler.schedule():
            t0 = time.perf_counter()
            S = self.max_prompt_len
            tokens = np.zeros((1, S), np.int32)
            valid = np.zeros((1, S), bool)
            n = len(req.prompt)
            tokens[0, :n] = req.prompt
            valid[0, :n] = True
            logits, single = self._prefill_fn(self.params, jnp.asarray(tokens),
                                              jnp.asarray(valid))
            self.cache = self._insert_fn(self.cache, single, slot=slot)
            s = self.sampling
            self._key, sk = jax.random.split(self._key)
            first = sample_tokens(sk, logits, temperature=s.temperature,
                                  top_k=s.top_k, top_p=s.top_p, greedy=s.greedy)
            first_id = int(jax.device_get(first)[0])
            req.output_tokens.append(first_id)
            self.cur_tokens[slot] = first_id
            self.active[slot] = True
            req.status = RequestStatus.RUNNING
            req.prefill_time = time.perf_counter() - t0
            self.stats.prefill_s += req.prefill_time
            self.stats.tokens_generated += 1
            self._maybe_finish(req)

    def _maybe_finish(self, req: Request) -> None:
        last = req.output_tokens[-1] if req.output_tokens else None
        if req.eos_token_id is not None and last == req.eos_token_id:
            req.status = RequestStatus.FINISHED_STOPPED
        elif req.num_generated >= req.max_new_tokens:
            req.status = RequestStatus.FINISHED_LENGTH
        if req.finished:
            self.active[req.slot] = False
            self.scheduler.retire(req)

    def step(self) -> bool:
        """One engine iteration: admit + one decode step. Returns whether
        any work remains."""
        self._admit()
        if not self.active.any():
            return self.scheduler.has_work()
        t0 = time.perf_counter()
        self._key, sk = jax.random.split(self._key)
        next_tok, self.cache = self._decode_fn(
            self.params, jnp.asarray(self.cur_tokens), self.cache,
            jnp.asarray(self.active), sk)
        next_np = np.asarray(jax.device_get(next_tok))
        dt = time.perf_counter() - t0
        self.stats.decode_s += dt
        self.stats.steps += 1
        for slot, req in self.scheduler.active():
            req.output_tokens.append(int(next_np[slot]))
            req.decode_times.append(dt)
            self.cur_tokens[slot] = next_np[slot]
            self.stats.tokens_generated += 1
            self._maybe_finish(req)
        return self.scheduler.has_work()

    def run(self, max_steps: int = 100_000) -> list[Request]:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return self.scheduler.finished

    def pool_stats(self) -> dict:
        """Fleet-level page-pool occupancy, aggregated over attention layers:
        total physical pages, pages on the free list, and utilization —
        the memory-reclamation signal the benchmarks report."""
        total = free = 0
        for lc in list(self.cache.pattern) + list(self.cache.tail):
            if lc.kv is None:
                continue
            ref = np.asarray(jax.device_get(lc.kv.ref_count))
            total += ref.size
            free += int((ref == 0).sum())
        return {"pool_pages": total, "free_pages": free,
                "utilization": (total - free) / total if total else 0.0}
