"""Continuous-batching serving engine (the vLLM-shaped runtime).

ONE unified step program (`models.transformer.forward_step`): each engine
iteration the scheduler packs up to ``token_budget`` tokens — one decode
token per RUNNING slot plus up to ``chunk_size`` prompt tokens per
PREFILLING slot — and a single jitted program appends them all straight
into the shared page pool, attends through block tables (paged
flash-prefill kernel on TPU), runs Alg.3 eviction on decode rows and
incremental Alg.2 compression at prefill chunk boundaries, and samples.
Decode-only iterations reuse the same function at T == 1, so a full mixed
workload compiles exactly two programs — there is no separate prefill
forward, no per-slot-specialized insert splice, and a long prompt never
stalls the decode slots sharing its batch (TTFT/ITL under mixed load is
what `benchmarks/latency.py` measures).

The eviction policy is a constructor argument — the paper's PagedEviction,
any baseline, or ``full``. Because every policy statically bounds the
per-request block table (budget + chunk headroom) and the pool is sized
for the full batch, admission can never over-commit HBM (DESIGN.md §2,
§6); pages a request evicts — or releases when it retires — return to the
SHARED free list and become headroom for every other request.

Telemetry per step: wall time split prefill/decode, tokens generated —
the benchmarks build the paper's throughput/TPOT/overhead tables from
these. :meth:`Engine.pool_stats` reports fleet-level pool occupancy.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CacheConfig, ModelConfig
from repro.core.policies import EvictionPolicy, get_policy
from repro.models.transformer import (
    ModelCache,
    forward_step,
    init_decode_caches,
    intact_prefix_pages,
)
from repro.serving.request import Request, RequestStatus, SamplingParams
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import Scheduler


@dataclass
class EngineStats:
    steps: int = 0               # every unified step (mixed + decode-only)
    decode_steps: int = 0        # decode-only steps — the ones whose wall
                                 # time lands in decode_s
    tokens_generated: int = 0    # every emitted token (mixed steps included)
    decode_tokens: int = 0       # tokens from decode-only steps
    pages_evicted: int = 0
    tokens_evicted: int = 0
    forced_evictions: int = 0
    shared_prefix_hits: int = 0   # admissions that adopted resident pages
    shared_prefix_tokens: int = 0  # prompt tokens whose prefill was skipped
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, cache_cfg: CacheConfig,
                 max_batch: int = 8, max_prompt_len: int = 256,
                 max_new_tokens: int = 128, sampling: SamplingParams | None = None,
                 use_pallas: bool = False, seed: int = 0,
                 chunk_size: int = 64, token_budget: int | None = None,
                 prefix_sharing: bool = True, decode_splits: int = 1,
                 fused_scores: bool | None = None):
        self.cfg = cfg
        self.params = params
        self.ccfg = cache_cfg
        self.policy: EvictionPolicy = get_policy(cache_cfg.policy)
        self.max_batch = max_batch
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.total_len = max_prompt_len + max_new_tokens
        self.sampling = sampling or SamplingParams()
        self.use_pallas = use_pallas
        # split-K decode (DESIGN.md §8): partition the page walk of the
        # Pallas decode kernel; 1 == off. Fused eviction scores default to
        # riding along whenever the Pallas kernels run (they emit the score
        # epilogue for free); pass False to force the stored-score path.
        self.decode_splits = decode_splits
        self.fused_scores = use_pallas if fused_scores is None else fused_scores
        self.chunk_size = min(chunk_size, max_prompt_len)
        # prefix sharing needs every layer's prompt state to live in paged
        # KV: recurrent mixers (mamba/xLSTM) and cross-attention state can't
        # be adopted page-wise, so sharing stays off for those archs
        self._sharing_ok = (prefix_sharing
                            and all(s.mixer == "attn"
                                    for s in cfg.layer_pattern())
                            and not cfg.cross_attention)
        self.scheduler = Scheduler(
            max_batch, chunk_size=self.chunk_size, token_budget=token_budget,
            page_size=cache_cfg.page_size if self._sharing_ok else None,
            prefix_probe=self._prefix_probe if self._sharing_ok else None)
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self._next_id = 0

        # batch-wide state (block tables carry chunk headroom: a prefilling
        # row transiently holds budget + chunk tokens between boundaries)
        self.cache: ModelCache = init_decode_caches(
            cfg, max_batch, self.total_len, self.policy, self.ccfg,
            chunk_tokens=self.chunk_size)
        self.cur_tokens = np.zeros((max_batch,), np.int32)

        self._step_fn = jax.jit(self._step_impl)
        self._probe_fn = jax.jit(intact_prefix_pages)

    # ---------------------------------------------------------------- jitted
    def _step_impl(self, params, tokens, n_tok, decode_mask, prefill_mask,
                   reset_mask, share_src, share_pages, cache, key):
        """The unified step: append + attend + evict + sample. Compiled once
        per token-dim T — the engine only ever calls it with T == chunk_size
        (mixed/prefill steps) and T == 1 (decode-only steps)."""
        logits, cache = forward_step(
            params, self.cfg, tokens, n_tok, cache, self.policy, self.ccfg,
            decode_mask=decode_mask, prefill_mask=prefill_mask,
            reset_mask=reset_mask, share_src=share_src,
            share_pages=share_pages, use_pallas=self.use_pallas,
            decode_splits=self.decode_splits, fused_scores=self.fused_scores)
        s = self.sampling
        next_tok = sample_tokens(key, logits, temperature=s.temperature,
                                 top_k=s.top_k, top_p=s.top_p, greedy=s.greedy)
        return next_tok, cache

    def _prefix_probe(self, slot: int) -> int:
        """Device half of prefix-sharing admission (scheduler callback):
        how many leading full prompt pages of batch row ``slot`` survive
        intact in every attention layer."""
        return int(self._probe_fn(self.cache, jnp.int32(slot)))

    # ------------------------------------------------------------------- api
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int | None = None,
               eos_token_id: int | None = None) -> Request:
        assert 0 < len(prompt) <= self.max_prompt_len, (
            f"prompt len {len(prompt)} not in (0, {self.max_prompt_len}]")
        req = Request(request_id=self._next_id,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens or self.max_new_tokens,
                      eos_token_id=eos_token_id)
        self._next_id += 1
        self.scheduler.add(req)
        return req

    def _maybe_finish(self, req: Request) -> None:
        last = req.output_tokens[-1] if req.output_tokens else None
        if req.eos_token_id is not None and last == req.eos_token_id:
            req.status = RequestStatus.FINISHED_STOPPED
        elif req.num_generated >= req.max_new_tokens:
            req.status = RequestStatus.FINISHED_LENGTH
        if req.finished:
            self.scheduler.retire(req)

    def step(self) -> bool:
        """One engine iteration: plan a unified step (admission + decode
        tokens + prompt chunks) and run it. Returns whether work remains."""
        plan = self.scheduler.plan()
        if plan.empty:
            return self.scheduler.has_work()
        B = self.max_batch
        T = self.chunk_size if plan.prefill else 1
        tokens = np.zeros((B, T), np.int32)
        n_tok = np.zeros((B,), np.int32)
        decode_mask = np.zeros((B,), bool)
        prefill_mask = np.zeros((B,), bool)
        reset_mask = np.zeros((B,), bool)
        reset_mask[plan.reset] = True
        share_src = np.full((B,), -1, np.int32)
        share_pages = np.zeros((B,), np.int32)
        for slot, src, n_pages in plan.adopt:
            share_src[slot] = src
            share_pages[slot] = n_pages
            self.stats.shared_prefix_hits += 1
            self.stats.shared_prefix_tokens += n_pages * self.ccfg.page_size
        for slot, req in plan.decode:
            tokens[slot, 0] = self.cur_tokens[slot]
            n_tok[slot] = 1
            decode_mask[slot] = True
        for slot, req, chunk, _ in plan.prefill:
            tokens[slot, :len(chunk)] = chunk
            n_tok[slot] = len(chunk)
            prefill_mask[slot] = True
            req.prefill_pos += len(chunk)

        t0 = time.perf_counter()
        self._key, sk = jax.random.split(self._key)
        next_tok, self.cache = self._step_fn(
            self.params, jnp.asarray(tokens), jnp.asarray(n_tok),
            jnp.asarray(decode_mask), jnp.asarray(prefill_mask),
            jnp.asarray(reset_mask), jnp.asarray(share_src),
            jnp.asarray(share_pages), self.cache, sk)
        next_np = np.asarray(jax.device_get(next_tok))
        dt = time.perf_counter() - t0
        now = time.perf_counter()
        self.stats.steps += 1
        if plan.prefill:
            self.stats.prefill_s += dt
        else:
            self.stats.decode_s += dt
            self.stats.decode_steps += 1

        for slot, req in plan.decode:
            req.output_tokens.append(int(next_np[slot]))
            req.decode_times.append(dt)
            self.cur_tokens[slot] = next_np[slot]
            self.stats.tokens_generated += 1
            if not plan.prefill:
                self.stats.decode_tokens += 1
            self._maybe_finish(req)
        for slot, req, chunk, completes in plan.prefill:
            req.prefill_time += dt
            if completes:
                # the sampled token at the prompt's last position is this
                # request's FIRST output token (its TTFT moment)
                req.output_tokens.append(int(next_np[slot]))
                req.first_token_time = now
                self.cur_tokens[slot] = next_np[slot]
                req.status = RequestStatus.RUNNING
                self.stats.tokens_generated += 1
                self._maybe_finish(req)
        return self.scheduler.has_work()

    def run(self, max_steps: int = 100_000) -> list[Request]:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        return self.scheduler.finished

    def num_compiled_programs(self) -> int:
        """Distinct compiled executables behind the engine (the per-slot
        recompilation family is dead: expect 2 — T == chunk and T == 1)."""
        size = getattr(self._step_fn, "_cache_size", None)
        return int(size()) if callable(size) else -1

    def pool_stats(self) -> dict:
        """Fleet-level page-pool occupancy, aggregated over attention layers:
        total physical pages, pages on the free list, utilization, and the
        prefix-sharing telemetry — pages mapped by more than one block table
        and the physical pages sharing saves (sum of ref_count - 1)."""
        total = free = shared = extra = 0
        for lc in list(self.cache.pattern) + list(self.cache.tail):
            if lc.kv is None:
                continue
            ref = np.asarray(jax.device_get(lc.kv.ref_count)).reshape(-1)
            total += ref.size
            free += int((ref == 0).sum())
            shared += int((ref > 1).sum())
            extra += int((ref[ref > 1] - 1).sum())
        return {"pool_pages": total, "free_pages": free,
                "utilization": (total - free) / total if total else 0.0,
                "shared_pages": shared, "pages_saved_by_sharing": extra}
