"""Token sampling: greedy / temperature / top-k / top-p, batched + jittable."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(key, logits, *, temperature: float = 1.0, top_k: int = 0,
                  top_p: float = 1.0, greedy: bool = False):
    """logits: (B, V) -> (B,) int32.

    Static sampling config (jit recompiles per config, which is what a
    serving engine wants: one compiled step per sampling class).
    """
    if greedy or temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    B, V = logits.shape
    if top_k and top_k < V:
        kth = jax.lax.top_k(logits, top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
