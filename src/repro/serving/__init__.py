"""Continuous-batching serving runtime."""
from repro.serving.engine import Engine, EngineStats
from repro.serving.request import Request, RequestStatus, SamplingParams
from repro.serving.sampler import sample_tokens
from repro.serving.scheduler import Scheduler, StepPlan

__all__ = ["Engine", "EngineStats", "Request", "RequestStatus",
           "SamplingParams", "sample_tokens", "Scheduler", "StepPlan"]
