"""PagedEviction core: paged KV cache + structured block-wise eviction."""
from repro.core.paged_cache import (
    PagedLayerCache,
    adopt_prefix,
    alloc_pages,
    append_chunk,
    chunk_rollover,
    fork_page,
    init_layer_cache,
    release_rows,
    row_intact_prefix_pages,
    write_token,
    write_prompt_pages,
    evict_page,
    evict_pages_mask,
    evict_token,
    evict_token_mask,
    find_free_slot,
    reclaim_empty_pages,
    start_new_page,
    to_contiguous,
)
from repro.core.policies import (
    POLICIES,
    EvictionOutcome,
    EvictionPolicy,
    FullCache,
    InverseKeyL2,
    KeyDiff,
    PagedEviction,
    StreamingLLM,
    get_policy,
)
from repro.core.prefill import compress_and_page
from repro.core.decode import decode_append
from repro.core import devstats, importance

__all__ = [
    "PagedLayerCache", "adopt_prefix", "alloc_pages", "append_chunk",
    "chunk_rollover", "fork_page", "init_layer_cache", "release_rows",
    "row_intact_prefix_pages", "write_token", "write_prompt_pages",
    "evict_page", "evict_pages_mask", "evict_token", "evict_token_mask",
    "find_free_slot", "reclaim_empty_pages", "start_new_page",
    "to_contiguous", "POLICIES", "EvictionOutcome", "EvictionPolicy",
    "FullCache", "InverseKeyL2", "KeyDiff", "PagedEviction", "StreamingLLM",
    "get_policy", "compress_and_page", "decode_append", "devstats",
    "importance",
]
