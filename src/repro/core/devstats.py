"""Device-side step statistics — the int32 stats vector (DESIGN.md §9).

The serving hot path must never host-callback out of the jitted step, yet
the telemetry layer (``repro.obs``) needs exact eviction/alloc/fork counts
per step. The contract: every :class:`~repro.core.paged_cache.PagedLayerCache`
optionally carries a tiny ``stats`` vector — shape ``(NSTATS,)`` int32 —
and each pool mutator accumulates its event counts into it with pure
``jnp`` scatter-adds as a byproduct of work it already does (the masks
being summed are values the mutators already computed). The unified step
zeroes each layer's vector on entry, so after one step the vector holds
exactly that step's counts; the engine sums the per-layer vectors on
device (``transformer.collect_step_stats``) and reconciles the single
(NSTATS,) array into the host registry once per step.

``stats is None`` disables tracking entirely (``None`` is a static Python
value under tracing, so the disabled path traces to the exact same HLO as
before this module existed — asserted by tests/test_obs.py).

Index semantics (counts are summed over B rows and, at the engine level,
over attention layers):

    PAGES_ALLOCATED   alloc_pages successes (a free page left the free list)
    PAGES_FREED       ref_count reached 0 (a page returned to the free list)
    PAGES_RELEASED    single-reference releases (block-table unmaps + CoW
                      source drops; the clamped decrements of _unref_pages)
    PAGES_ADOPTED     prefix-sharing block-table mappings (ref bumps)
    PAGES_FORKED      copy-on-write forks that actually copied
    PAGES_EVICTED     policy page-level evictions (incl. forced)
    TOKENS_EVICTED    token-level evictions that invalidated a live token
    FORCED_EVICTIONS  fragmentation force-evicts (rollover found no free page)
    TOKENS_WRITTEN    write_token appends that landed

Conservation identities (exact; tests/test_obs.py checks them against
host-recomputed pool state every step of a churned mixed workload):

    Δ sum(ref_count)  == PAGES_ALLOCATED + PAGES_ADOPTED - PAGES_RELEASED
    Δ free_pages      == PAGES_FREED - PAGES_ALLOCATED
    Δ mapped_entries  == PAGES_ALLOCATED + PAGES_ADOPTED - PAGES_RELEASED
                         (every block-table entry holds exactly one
                         reference: F2 — forks alloc + release in pairs, so
                         they cancel here, as they must)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PAGES_ALLOCATED = 0
PAGES_FREED = 1
PAGES_RELEASED = 2
PAGES_ADOPTED = 3
PAGES_FORKED = 4
PAGES_EVICTED = 5
TOKENS_EVICTED = 6
FORCED_EVICTIONS = 7
TOKENS_WRITTEN = 8
NSTATS = 9

STAT_NAMES = (
    "pages_allocated", "pages_freed", "pages_released", "pages_adopted",
    "pages_forked", "pages_evicted", "tokens_evicted", "forced_evictions",
    "tokens_written",
)


def zeros() -> jax.Array:
    return jnp.zeros((NSTATS,), jnp.int32)


def bump(stats, idx: int, count):
    """stats.at[idx] += sum(count); identity (None) when tracking is off.
    ``count`` may be a bool/int array of any shape — it is summed."""
    if stats is None:
        return None
    return stats.at[idx].add(jnp.sum(count).astype(jnp.int32))


def to_dict(stats) -> dict:
    """Host-side: (NSTATS,) array/ndarray -> {name: int}."""
    return {name: int(stats[i]) for i, name in enumerate(STAT_NAMES)}
