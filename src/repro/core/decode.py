"""Paper Algorithm 3 — decode-phase block-wise compression.

One call per generated token per layer: append K/V to the write head, then
let the policy do its bookkeeping (page rollover; PagedEviction evicts an
entire page only when the newest page just became full; token-level
baselines evict one token per step — reproducing the paper's overhead
asymmetry by construction).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import CacheConfig
from repro.core.paged_cache import PagedLayerCache, write_token
from repro.core.policies import EvictionOutcome, EvictionPolicy


def decode_append(cache: PagedLayerCache, k_tok, v_tok, pos_tok,
                  policy: EvictionPolicy, cfg: CacheConfig,
                  active=None) -> EvictionOutcome:
    """Append one token per request and run the policy's eviction hook.

    k_tok, v_tok: (B, KV, hd); pos_tok: (B,) int32.
    Returns the updated cache plus eviction telemetry.
    """
    score = policy.write_score(k_tok, v_tok, pos_tok)
    cache = write_token(cache, k_tok, v_tok, pos_tok, score, active=active)
    return policy.post_write(cache, cfg, active=active)
