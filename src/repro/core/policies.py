"""Eviction policies (the paper's technique + all its baselines).

Every policy is a stateless, hashable strategy object with three hooks:

  write_score(k_tok, v_tok, pos)        score stored with each written token
  prefill_keep(k, v, positions, valid)  paper Alg.2, one-shot form — token-
                                        level prompt compression to the
                                        budget *before* paging (offline /
                                        whole-prompt flows)
  chunk_prefill_evict(cache, cfg, ...)  paper Alg.2, incremental form — at
                                        each chunked-prefill boundary,
                                        compress the pooled cache back to
                                        budget (PagedEviction: evict whole
                                        COMPLETED pages; token policies:
                                        keep the top-C tokens). Evicting the
                                        minimum-score completed page whenever
                                        the count exceeds budget_pages is a
                                        running top-K, so the surviving page
                                        set is chunk-size invariant.
  post_write(cache, cfg, active)        paper Alg.3 — decode-time bookkeeping
                                        after each appended token: page
                                        rollover, eviction, block-table update

Both eviction hooks accept an optional ``page_scores`` (B, P) array — the
attention kernels' fused score epilogue (DESIGN.md §8). When provided and
usable, PagedEviction ranks pages by it instead of touching
``cache.page_scores()``, so eviction metadata costs nothing beyond the
attention pass the step already ran. Policies that don't rank by page
score ignore it; windowed chunk eviction falls back to the stored path
(out-of-window drops invalidate scores computed at attention time).

Telemetry (DESIGN.md §9): policies need no instrumentation of their own —
every pool mutation they invoke (``evict_page``, ``evict_token[_mask]``,
``rollover_to_free_page`` force-evicts, CoW forks) bumps the cache's
device stats vector inside ``paged_cache.py``, so per-policy eviction
counts fall out of the ``pool.*`` counters for free.

Policies:
  paged_eviction   the paper: structured block-wise eviction at page-full
                   boundaries using S = ||V||/||K|| page means
  full             no eviction (slab sized to the sequence)
  streaming_llm    sinks + sliding window; one token evicted per step
  inverse_key_l2   unstructured: evict highest ||K|| token per step
  keydiff          unstructured: evict least-diverse key per step (global
                   cosine-vs-mean recomputed each step — deliberately costly,
                   reproducing the paper's overhead comparison)

All hooks are shape-static and jit/vmap/scan-safe.

Shared pages (prefix sharing, DESIGN.md §7): no policy needs to know about
``ref_count > 1`` — the primitives they compose enforce the semantics.
Page-level eviction (``evict_pages_mask``, the paper's Alg.2/Alg.3 path)
of a shared page is an unmap: this request's budget drops by a page but the
data stays live for the other mappers, and the physical page is only
recycled when the last mapper lets go. Token-level eviction
(``evict_token`` / ``evict_token_mask``, the unstructured baselines)
copy-on-write-forks a shared page before mutating — at most one fork per
row per call, so a baseline that targets many shared pages converges over
a few steps, transiently exceeding budget rather than ever corrupting a
sharer's view.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import CacheConfig
from repro.core import importance
from repro.core.paged_cache import (
    PagedLayerCache,
    alloc_pages,
    evict_page,
    evict_pages_mask,
    evict_token,
    evict_token_mask,
    find_free_slot,
    reclaim_empty_pages,
    rollover_to_free_page,
    start_new_page,
)


class EvictionOutcome(NamedTuple):
    cache: PagedLayerCache
    pages_evicted: jax.Array    # (B,) bool — a full page was evicted
    tokens_evicted: jax.Array   # (B,) bool — a single token was evicted
    forced_evictions: jax.Array  # (B,) bool — fragmentation forced a page out
    # forensics (obs/lineage.py): which logical page lost the argmin and at
    # what policy score. Only meaningful where pages_evicted is True; None
    # for policies that never evict whole pages (token-granular baselines).
    victim_page: jax.Array | None = None    # (B,) int32 logical page index
    victim_score: jax.Array | None = None   # (B,) f32 score at eviction


def _no_evict(cache):
    B = cache.batch
    false = jnp.zeros((B,), bool)
    return false, false


def _rollover_to_free_page(cache: PagedLayerCache, need):
    """Where ``need``, allocate a fresh physical page from the SHARED pool,
    map it into the first unmapped logical slot, and move the write head
    there. Fully-emptied mapped pages (token-level eviction holes) are
    reclaimed to the free list first, so one request's evictions become
    every other request's headroom. If a request has no unmapped slot or the
    pool has no free page (unstructured fragmentation / overcommit),
    force-evict its fullest-but-not-current page with the fewest valid
    tokens, which releases both a slot and a physical page.

    The whole body runs under ``lax.cond`` on ``any(need)``: pages fill once
    per page_size steps, so the reclaim/alloc bookkeeping is skipped on the
    other page_size - 1 steps (the overhead benchmarks measure this). The
    branches are module-level functions so eager callers hit the cond's
    compile cache across steps."""
    return jax.lax.cond(jnp.any(need), _rollover_body, _rollover_noop,
                        (cache, need))


def _rollover_noop(args):
    cache, need = args
    return cache, jnp.zeros((cache.batch,), bool)


def _out_of_window(cache: PagedLayerCache, window: int, active):
    """(B, P, page) bool — live tokens a windowed layer can never attend
    again (pos <= newest - window). Dropping them at a chunk boundary is
    exactly equivalence-preserving: any later query's window mask excludes
    them too, so no attention result changes."""
    pos = cache.pos_view()
    valid = pos >= 0
    cur = jnp.max(jnp.where(valid, pos, -1), axis=(1, 2), keepdims=True)
    return valid & (pos <= cur - window) & active[:, None, None]


def _rollover_body(args):
    cache, need = args
    return rollover_to_free_page(cache, need)


class EvictionPolicy:
    name: str = "base"
    structured: bool = True

    def __init__(self, tp_axis: str | None = None):
        # Tensor parallelism (DESIGN.md §11): when the KV-head axis is
        # sharded over a shard_map mesh axis, score reductions over KV
        # heads must pmean across it so every shard ranks tokens/pages by
        # the GLOBAL score and eviction picks identical victims. None (the
        # registry singletons) keeps all reductions local — byte-identical
        # to the pre-TP behaviour.
        self.tp_axis = tp_axis

    # --- slab sizing --------------------------------------------------------
    def _round_slab(self, cfg: CacheConfig, pages: int) -> int:
        m = max(cfg.slab_multiple, 1)
        return -(-pages // m) * m

    def slab_pages(self, cfg: CacheConfig, seq_len: int) -> int:
        total = -(-seq_len // cfg.page_size)
        return self._round_slab(cfg, min(total, cfg.budget_pages + 1))

    # --- scores -------------------------------------------------------------
    def write_score(self, k_tok, v_tok, pos_tok):
        """k_tok, v_tok: (B, KV, hd) -> (B,) f32."""
        raise NotImplementedError

    def prefill_scores(self, k, v, positions):
        """k, v: (B, S, KV, hd); positions (B, S) -> (B, S) f32."""
        raise NotImplementedError

    # --- Alg.2: prefill compression ------------------------------------------
    def prefill_keep(self, k, v, positions, valid, cfg: CacheConfig):
        """Select ``keep = min(budget, S_pad)`` tokens. Returns
        (indices (B, keep) in ascending position order, scores (B, S))."""
        B, S = positions.shape
        keep = min(cfg.cache_budget, S)
        scores = self.prefill_scores(k, v, positions)
        scores = jnp.where(valid, scores, -jnp.inf)
        _, idx = jax.lax.top_k(scores, keep)               # (B, keep)
        idx = jnp.sort(idx, axis=-1)                       # restore order
        return idx, scores

    # --- Alg.2, incremental: chunk-boundary compression ----------------------
    def _evict_scores(self, cache: PagedLayerCache, cfg: CacheConfig):
        """(B, P, page) dynamic importance used by chunk/token eviction;
        defaults to the stored write scores."""
        return cache.score_view()

    def chunk_prefill_evict(self, cache: PagedLayerCache, cfg: CacheConfig,
                            active=None, window: int = 0,
                            page_scores=None) -> PagedLayerCache:
        """Compress the pooled cache back to the budget at a chunked-prefill
        boundary (incremental Alg.2). ``active``: (B,) bool — rows that
        consumed a prompt chunk this step; ``window``: the layer's attention
        window (out-of-window tokens are dropped first — they can never be
        attended again); ``page_scores``: optional (B, P) fused-epilogue
        scores (see module docstring). The whole body runs under
        ``lax.cond`` so pure-decode steps skip it."""
        if active is None:
            active = jnp.ones((cache.batch,), bool)
        return jax.lax.cond(
            jnp.any(active),
            lambda c: self._chunk_evict_body(c, cfg, active, window,
                                             page_scores),
            lambda c: c, cache)

    def _chunk_evict_body(self, cache, cfg: CacheConfig, active, window: int,
                          page_scores=None):
        """Token-level default: keep the top-C live tokens by eviction score
        (rank via stable argsort — ties keep the older token), then return
        fully-emptied pages to the shared free list. Token policies rank
        per-token, so the fused page_scores don't apply."""
        del page_scores
        B, P, page = cache.batch, cache.num_pages, cache.page_size
        if window:
            cache = evict_token_mask(cache, _out_of_window(cache, window,
                                                           active))
        valid = cache.valid_mask()
        scores = jnp.where(valid, self._evict_scores(cache, cfg), -jnp.inf)
        order = jnp.argsort(-scores.reshape(B, -1), axis=-1)
        ranks = jnp.argsort(order, axis=-1)                 # 0 == best
        evict = valid.reshape(B, -1) & (ranks >= cfg.cache_budget) & \
            active[:, None]
        cache = evict_token_mask(cache, evict.reshape(B, P, page))
        return reclaim_empty_pages(cache)

    # --- Alg.3: decode bookkeeping -------------------------------------------
    def post_write(self, cache: PagedLayerCache, cfg: CacheConfig,
                   active=None, page_scores=None) -> EvictionOutcome:
        raise NotImplementedError

    # ------------------------------------------------------------------ misc
    def __hash__(self):
        return hash((self.name, self.tp_axis))

    def __eq__(self, other):
        return (type(self) is type(other)
                and self.tp_axis == getattr(other, "tp_axis", None))

    def __repr__(self):
        if self.tp_axis is not None:
            return f"{type(self).__name__}(tp_axis={self.tp_axis!r})"
        return f"{type(self).__name__}()"


# ---------------------------------------------------------------------------
# Full cache (no eviction)
# ---------------------------------------------------------------------------

class FullCache(EvictionPolicy):
    name = "full"
    structured = True

    def slab_pages(self, cfg, seq_len):
        return self._round_slab(cfg, -(-seq_len // cfg.page_size))

    def write_score(self, k_tok, v_tok, pos_tok):
        return jnp.zeros(k_tok.shape[:-2], jnp.float32)

    def prefill_scores(self, k, v, positions):
        # recency: irrelevant when nothing is dropped; for windowed layers
        # the slab-capacity cap (compress_and_page) then keeps the newest
        return importance.recency_score(positions)

    def prefill_keep(self, k, v, positions, valid, cfg):
        B, S = positions.shape
        idx = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return idx, jnp.where(valid, self.prefill_scores(k, v, positions),
                              -jnp.inf)

    def _chunk_evict_body(self, cache, cfg, active, window: int,
                          page_scores=None):
        # no budget: only windowed layers shed (never-again-attendable) tokens
        del page_scores
        if window:
            cache = evict_token_mask(cache, _out_of_window(cache, window,
                                                           active))
            cache = reclaim_empty_pages(cache)
        return cache

    def post_write(self, cache, cfg, active=None, page_scores=None):
        del page_scores
        if active is None:
            active = jnp.ones((cache.batch,), bool)
        need = active & (cache.cur_off >= cache.page_size)
        cache = jax.lax.cond(jnp.any(need), _full_grow_body, _full_grow_noop,
                             (cache, need))
        t, f = _no_evict(cache)
        return EvictionOutcome(cache, t, t, f)


def _full_grow_noop(args):
    return args[0]


def _full_grow_body(args):
    cache, need = args
    slot, slot_ok = find_free_slot(cache)
    cache, phys, ok = alloc_pages(cache, need & slot_ok)
    grow = need & slot_ok & ok
    cache = start_new_page(cache, slot, phys, enable=grow)
    # saturated (block table exhausted — callers size slabs so this only
    # happens after the final token): never evict; park the head on the
    # full current page with off reset, mirroring the old clamp
    return cache._replace(cur_off=jnp.where(need & ~grow, 0, cache.cur_off))


# ---------------------------------------------------------------------------
# PagedEviction (the paper)
# ---------------------------------------------------------------------------

class PagedEviction(EvictionPolicy):
    """Structured block-wise eviction (paper Alg. 1-3)."""
    name = "paged_eviction"
    structured = True

    def write_score(self, k_tok, v_tok, pos_tok):
        return importance.vk_ratio_score(k_tok, v_tok, axis_name=self.tp_axis)

    def prefill_scores(self, k, v, positions):
        return importance.vk_ratio_score(k, v, axis_name=self.tp_axis)

    def _chunk_evict_body(self, cache, cfg, active, window: int,
                          page_scores=None):
        """Structured chunk-boundary compression: evict the lowest-mean-score
        COMPLETED pages until at most ``budget_pages`` remain (the partial
        working page rides free, mirroring Alg.3's budget+page slack).
        Because candidacy is by completion and the minimum is always evicted
        first, the surviving page set equals the overall top-K — chunk-size
        invariant whenever attention inputs are (see DESIGN.md §6).

        ``page_scores``: fused-epilogue scores from the attention pass this
        step already ran (DESIGN.md §8) — used instead of the stored-score
        reduction when the layer is unwindowed. Windowed layers drop
        out-of-window tokens first, which changes page means, so they fall
        back to scoring the post-drop cache."""
        if window:
            page_scores = None      # stale after the out-of-window drop
            cache = evict_token_mask(cache, _out_of_window(cache, window,
                                                           active))
        full = cache.tokens_per_page() >= cache.page_size   # (B, P) completed
        if cfg.protect_recent:
            B, P = full.shape
            full &= ~jax.nn.one_hot(cache.cur_page, P, dtype=bool)
        m = jnp.maximum(jnp.sum(full, axis=-1) - cfg.budget_pages, 0)  # (B,)
        pscores = cache.page_scores() if page_scores is None else page_scores
        cand = jnp.where(full, pscores, jnp.inf)
        order = jnp.argsort(cand, axis=-1)
        ranks = jnp.argsort(order, axis=-1)                 # 0 == worst
        evict = full & (ranks < m[:, None]) & active[:, None]
        cache = evict_pages_mask(cache, evict)
        return reclaim_empty_pages(cache)

    def post_write(self, cache, cfg, active=None, page_scores=None):
        if active is None:
            active = jnp.ones((cache.batch,), bool)
        page_full = active & (cache.cur_off >= cache.page_size)
        over = cache.total_valid() > cfg.cache_budget
        do_evict = page_full & over
        # page score = mean ||V||/||K|| over the page (Alg.1 block mode);
        # only *full* pages compete (the working page is the one just filled,
        # already full; under-filled pages only exist transiently). The
        # fused-epilogue scores, when passed, are this exact reduction
        # computed for free inside the attention kernel (DESIGN.md §8).
        pscores = cache.page_scores() if page_scores is None else page_scores
        full_pages = cache.tokens_per_page() >= cache.page_size
        if cfg.protect_recent:
            B, P = pscores.shape
            cur = jax.nn.one_hot(cache.cur_page, P, dtype=bool)
            full_pages &= ~cur
        cand = jnp.where(full_pages, pscores, jnp.inf)
        victim = jnp.argmin(cand, axis=-1).astype(jnp.int32)
        vscore = jnp.take_along_axis(pscores, victim[:, None],
                                     axis=-1)[:, 0].astype(jnp.float32)
        cache = evict_page(cache, victim, enable=do_evict)
        cache, forced = _rollover_to_free_page(cache, page_full)
        return EvictionOutcome(cache, do_evict,
                               jnp.zeros((cache.batch,), bool), forced,
                               victim_page=victim, victim_score=vscore)


# ---------------------------------------------------------------------------
# StreamingLLM (sinks + sliding window; token-per-step)
# ---------------------------------------------------------------------------

class StreamingLLM(EvictionPolicy):
    name = "streaming_llm"
    structured = True  # paper classifies it as structured (within-block order)

    def slab_pages(self, cfg, seq_len):
        total = -(-seq_len // cfg.page_size)
        # sinks pin their page forever -> one extra slot of headroom
        return self._round_slab(cfg, min(total, cfg.budget_pages + 2))

    def write_score(self, k_tok, v_tok, pos_tok):
        return importance.recency_score(pos_tok)

    def prefill_scores(self, k, v, positions):
        return importance.recency_score(positions)

    def prefill_keep(self, k, v, positions, valid, cfg):
        B, S = positions.shape
        keep = min(cfg.cache_budget, S)
        # sinks get +inf so they always survive; others ranked by recency
        scores = importance.recency_score(positions)
        scores = jnp.where(positions < cfg.num_sink_tokens, jnp.inf, scores)
        scores = jnp.where(valid, scores, -jnp.inf)
        _, idx = jax.lax.top_k(scores, keep)
        return jnp.sort(idx, axis=-1), scores

    def _evict_scores(self, cache, cfg):
        # sinks pinned with +inf so budget compression never drops them;
        # everything else ranked by the stored recency score
        return jnp.where(cache.pos_view() < cfg.num_sink_tokens,
                         jnp.inf, cache.score_view())

    def post_write(self, cache, cfg, active=None, page_scores=None):
        del page_scores                                     # ranks by recency
        if active is None:
            active = jnp.ones((cache.batch,), bool)
        over = active & (cache.total_valid() > cfg.cache_budget)
        valid = cache.valid_mask()
        B, P, page = valid.shape
        # oldest non-sink token
        pos = cache.pos_view()
        cand = jnp.where(valid & (pos >= cfg.num_sink_tokens),
                         pos, jnp.iinfo(jnp.int32).max)
        flat = cand.reshape(B, P * page)
        victim = jnp.argmin(flat, axis=-1).astype(jnp.int32)
        cache = evict_token(cache, victim, enable=over)
        need = active & (cache.cur_off >= cache.page_size)
        cache, forced = _rollover_to_free_page(cache, need)
        return EvictionOutcome(cache, jnp.zeros((B,), bool), over, forced)


# ---------------------------------------------------------------------------
# Unstructured baselines (token-per-step across pages)
# ---------------------------------------------------------------------------

class _UnstructuredTokenPolicy(EvictionPolicy):
    structured = False

    def slab_pages(self, cfg, seq_len):
        total = -(-seq_len // cfg.page_size)
        # token-level holes fragment pages (paper Limitation 1/Fig. 6): a page
        # frees only when *all* its tokens have been individually evicted, so
        # the working set needs headroom beyond budget/page_size.
        return self._round_slab(cfg, min(total, 2 * cfg.budget_pages + 2))

    def post_write(self, cache, cfg, active=None, page_scores=None):
        del page_scores                                     # ranks per-token
        if active is None:
            active = jnp.ones((cache.batch,), bool)
        over = active & (cache.total_valid() > cfg.cache_budget)
        valid = cache.valid_mask()
        B, P, page = valid.shape
        scores = jnp.where(valid, self._evict_scores(cache, cfg), jnp.inf)
        victim = jnp.argmin(scores.reshape(B, P * page), axis=-1).astype(jnp.int32)
        cache = evict_token(cache, victim, enable=over)
        need = active & (cache.cur_off >= cache.page_size)
        cache, forced = _rollover_to_free_page(cache, need)
        return EvictionOutcome(cache, jnp.zeros((B,), bool), over, forced)


class InverseKeyL2(_UnstructuredTokenPolicy):
    name = "inverse_key_l2"

    def write_score(self, k_tok, v_tok, pos_tok):
        return importance.inverse_key_l2_score(k_tok, axis_name=self.tp_axis)

    def prefill_scores(self, k, v, positions):
        return importance.inverse_key_l2_score(k, axis_name=self.tp_axis)


class KeyDiff(_UnstructuredTokenPolicy):
    name = "keydiff"

    def write_score(self, k_tok, v_tok, pos_tok):
        # keydiff importance is global (needs the mean key) -> computed at
        # eviction time from the live cache; stored score is unused.
        return jnp.zeros(k_tok.shape[:-2], jnp.float32)

    def prefill_scores(self, k, v, positions):
        mean = jnp.mean(k.astype(jnp.float32), axis=1, keepdims=True)
        return importance.keydiff_score(k, mean, axis_name=self.tp_axis)

    def _evict_scores(self, cache, cfg):
        valid = cache.valid_mask()                          # (B,P,page)
        kf = cache.k_view().astype(jnp.float32)
        w = valid[..., None, None].astype(jnp.float32)
        # per-KV-head mean over tokens — shard-local under TP (each shard
        # owns whole heads); only the final cos mean crosses heads
        mean = jnp.sum(kf * w, axis=(1, 2)) / jnp.maximum(
            jnp.sum(w, axis=(1, 2)), 1.0)                   # (B,KV,hd)
        return importance.keydiff_score(kf, mean[:, None, None],
                                        axis_name=self.tp_axis)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

POLICIES: dict[str, EvictionPolicy] = {
    p.name: p
    for p in (FullCache(), PagedEviction(), StreamingLLM(), InverseKeyL2(), KeyDiff())
}


def get_policy(name: str, tp_axis: str | None = None) -> EvictionPolicy:
    """Look up a policy. ``tp_axis`` (tensor-parallel serving only) returns
    a fresh instance whose KV-head score reductions pmean over that mesh
    axis; the default returns the shared local-reduction singleton."""
    try:
        pol = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; available: {sorted(POLICIES)}") from None
    if tp_axis is None:
        return pol
    return type(pol)(tp_axis=tp_axis)
