"""Token / block importance proxies (paper §4.1, Algorithm 1).

All scores follow the convention **higher = more important = keep**; the
eviction argmin removes the least important token/page.

The paper's proxy:  S_i = ||V_i||_2 / ||K_i||_2
  - ||V_i|| large  -> the token carries much content into the output.
  - ||K_i|| small  -> (Devoto et al. 2024) inversely correlated with the
    token's cumulative attention weight, so 1/||K_i|| is a cheap stand-in
    for attention mass.
Computed from static K/V states only — never needs the attention matrix,
hence compatible with fused/flash kernels (paper Limitation 3).

Scores are aggregated over KV heads (mean) so eviction decisions are
uniform per layer, keeping one block table per (request, layer) exactly as
vLLM does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-6


def _norms(x):
    """L2 norm over head_dim. x: (..., KV, hd) -> (...,) mean over KV heads."""
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1)   # (..., KV)
    return jnp.mean(n, axis=-1)


def vk_ratio_score(k, v):
    """Paper Alg.1 token importance: mean_h(||V||) / mean_h(||K||).

    k, v: (..., KV, hd)  ->  (...,) f32.
    """
    return _norms(v) / jnp.maximum(_norms(k), _EPS)


def inverse_key_l2_score(k, v=None):
    """Devoto et al. 2024 baseline: evict tokens with *high* key L2 norm,
    i.e. importance = -||K||. (..., KV, hd) -> (...,)."""
    del v
    return -_norms(k)


def keydiff_score(k, key_mean):
    """KeyDiff (Park et al. 2025) baseline: evict tokens whose keys are most
    similar to the mean key direction (least diverse). importance =
    -cos(k_i, k_mean), averaged over KV heads.

    k: (..., KV, hd); key_mean: broadcastable (..., KV, hd) mean key.
    """
    kf = k.astype(jnp.float32)
    mf = key_mean.astype(jnp.float32)
    num = jnp.sum(kf * mf, axis=-1)
    den = jnp.maximum(jnp.linalg.norm(kf, axis=-1) * jnp.linalg.norm(mf, axis=-1), _EPS)
    cos = num / den                                        # (..., KV)
    return -jnp.mean(cos, axis=-1)


def recency_score(positions):
    """StreamingLLM ordering: newer = more important. positions: (...)."""
    return positions.astype(jnp.float32)


def page_scores_from_norms(kn, vn, pos_pages, mapped):
    """Paper Alg.1 page scores from the attention kernels' fused norm
    epilogue (DESIGN.md §8) — the free path for `block_score`.

    kn, vn: (B, KV, P, page) per-token K/V L2 norms (byproduct outputs of
    the decode/prefill Pallas kernels); pos_pages: (B, P, page) token
    positions with -1 for empty slots (``cache.pos_view()``); mapped:
    (B, P) bool (``cache.mapped_mask()``). Returns (B, P) f32; empty or
    unmapped pages score +inf (never the eviction argmin). Numerically
    identical to running the standalone ``block_score`` pool pass and
    gathering through the block table — that pass survives as the parity
    oracle (tests/test_kernel_perf.py).
    """
    tok = jnp.mean(vn, axis=1) / jnp.maximum(jnp.mean(kn, axis=1), _EPS)
    valid = (pos_pages >= 0) & mapped[:, :, None]           # (B, P, page)
    cnt = jnp.sum(valid, axis=-1)
    ssum = jnp.sum(jnp.where(valid, tok, 0.0), axis=-1)
    return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)


def block_scores_from_token_scores(token_scores, valid, page_size: int):
    """Paper Alg.1 block mode: S_j = mean_{i in block j} S_i.

    token_scores: (..., S) with S % page_size == 0; valid: same-shape bool.
    Returns (..., S // page_size); empty blocks -> +inf (never evicted first).
    """
    *lead, S = token_scores.shape
    assert S % page_size == 0
    ts = token_scores.reshape(*lead, S // page_size, page_size)
    vm = valid.reshape(*lead, S // page_size, page_size)
    cnt = jnp.sum(vm, axis=-1)
    ssum = jnp.sum(jnp.where(vm, ts, 0.0), axis=-1)
    return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)
