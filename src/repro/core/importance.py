"""Token / block importance proxies (paper §4.1, Algorithm 1).

All scores follow the convention **higher = more important = keep**; the
eviction argmin removes the least important token/page.

The paper's proxy:  S_i = ||V_i||_2 / ||K_i||_2
  - ||V_i|| large  -> the token carries much content into the output.
  - ||K_i|| small  -> (Devoto et al. 2024) inversely correlated with the
    token's cumulative attention weight, so 1/||K_i|| is a cheap stand-in
    for attention mass.
Computed from static K/V states only — never needs the attention matrix,
hence compatible with fused/flash kernels (paper Limitation 3).

Scores are aggregated over KV heads (mean) so eviction decisions are
uniform per layer, keeping one block table per (request, layer) exactly as
vLLM does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-6


def _norms(x, axis_name=None):
    """L2 norm over head_dim. x: (..., KV, hd) -> (...,) mean over KV heads.

    Under tensor parallelism the KV-head axis is sharded over ``axis_name``;
    the local mean is then ``pmean``'d so every shard sees the GLOBAL
    per-token mean and eviction decisions stay identical across TP degrees
    (equal local head counts make mean-of-means exact).
    """
    n = jnp.linalg.norm(x.astype(jnp.float32), axis=-1)   # (..., KV)
    m = jnp.mean(n, axis=-1)
    if axis_name is not None:
        m = jax.lax.pmean(m, axis_name)
    return m


def vk_ratio_score(k, v, axis_name=None):
    """Paper Alg.1 token importance: mean_h(||V||) / mean_h(||K||).

    k, v: (..., KV, hd)  ->  (...,) f32. The KV-head means are globalised
    (pmean) BEFORE the nonlinear ratio so sharded and unsharded scores agree.
    """
    return (_norms(v, axis_name)
            / jnp.maximum(_norms(k, axis_name), _EPS))


def inverse_key_l2_score(k, v=None, axis_name=None):
    """Devoto et al. 2024 baseline: evict tokens with *high* key L2 norm,
    i.e. importance = -||K||. (..., KV, hd) -> (...,)."""
    del v
    return -_norms(k, axis_name)


def keydiff_score(k, key_mean, axis_name=None):
    """KeyDiff (Park et al. 2025) baseline: evict tokens whose keys are most
    similar to the mean key direction (least diverse). importance =
    -cos(k_i, k_mean), averaged over KV heads.

    k: (..., KV, hd); key_mean: broadcastable (..., KV, hd) mean key.
    """
    kf = k.astype(jnp.float32)
    mf = key_mean.astype(jnp.float32)
    num = jnp.sum(kf * mf, axis=-1)
    den = jnp.maximum(jnp.linalg.norm(kf, axis=-1) * jnp.linalg.norm(mf, axis=-1), _EPS)
    cos = num / den                                        # (..., KV)
    m = -jnp.mean(cos, axis=-1)
    if axis_name is not None:
        m = jax.lax.pmean(m, axis_name)
    return m


def recency_score(positions):
    """StreamingLLM ordering: newer = more important. positions: (...)."""
    return positions.astype(jnp.float32)


def page_scores_from_norms(kn, vn, pos_pages, mapped, axis_name=None):
    """Paper Alg.1 page scores from the attention kernels' fused norm
    epilogue (DESIGN.md §8) — the free path for `block_score`.

    kn, vn: (B, KV, P, page) per-token K/V L2 norms (byproduct outputs of
    the decode/prefill Pallas kernels); pos_pages: (B, P, page) token
    positions with -1 for empty slots (``cache.pos_view()``); mapped:
    (B, P) bool (``cache.mapped_mask()``). Returns (B, P) f32; empty or
    unmapped pages score +inf (never the eviction argmin). Numerically
    identical to running the standalone ``block_score`` pool pass and
    gathering through the block table — that pass survives as the parity
    oracle (tests/test_kernel_perf.py).

    Under TP the kernels emit norms for LOCAL KV heads only; ``axis_name``
    pmeans the head means before the ratio so the page scores every shard
    feeds into the eviction argmin are the global ones.
    """
    km = jnp.mean(kn, axis=1)
    vm = jnp.mean(vn, axis=1)
    if axis_name is not None:
        km = jax.lax.pmean(km, axis_name)
        vm = jax.lax.pmean(vm, axis_name)
    tok = vm / jnp.maximum(km, _EPS)
    valid = (pos_pages >= 0) & mapped[:, :, None]           # (B, P, page)
    cnt = jnp.sum(valid, axis=-1)
    ssum = jnp.sum(jnp.where(valid, tok, 0.0), axis=-1)
    return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)


def block_scores_from_token_scores(token_scores, valid, page_size: int):
    """Paper Alg.1 block mode: S_j = mean_{i in block j} S_i.

    token_scores: (..., S) with S % page_size == 0; valid: same-shape bool.
    Returns (..., S // page_size); empty blocks -> +inf (never evicted first).
    """
    *lead, S = token_scores.shape
    assert S % page_size == 0
    ts = token_scores.reshape(*lead, S // page_size, page_size)
    vm = valid.reshape(*lead, S // page_size, page_size)
    cnt = jnp.sum(vm, axis=-1)
    ssum = jnp.sum(jnp.where(vm, ts, 0.0), axis=-1)
    return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)
