"""Functional paged KV cache — the TPU/JAX analogue of vLLM's block pool.

Layout (per attention layer):
    k, v   : (B, P, page, KV, hd)   physical page slab per request
    pos    : (B, P, page) int32     original token position; -1 == invalid
    score  : (B, P, page) float32   per-token policy score (higher == keep)
    cur_page, cur_off : (B,) int32  write head (page slot, offset)

Under an eviction policy with budget C and page size Bp, P is statically
``C/Bp + 1`` — the budget makes the working set a *static* shape, which is
exactly what XLA wants (vLLM needs a dynamic allocator for the same thing;
see DESIGN.md §2). Under ``full`` policy P covers the whole sequence.

Evicting a page == zeroing its validity; the physical slot is then reused
by the next page of tokens. No data movement, ever (the paper's point).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class PagedLayerCache(NamedTuple):
    k: jax.Array          # (B, P, page, KV, hd) — bf16/f32, or int8 (quantized)
    v: jax.Array          # (B, P, page, KV, hd)
    pos: jax.Array        # (B, P, page) int32, -1 invalid
    score: jax.Array      # (B, P, page) f32, -inf invalid
    cur_page: jax.Array   # (B,) int32
    cur_off: jax.Array    # (B,) int32
    # int8 mode (beyond-paper: the quantized-KV composition the paper cites
    # as future work): absmax scale per (token, head); None when not quantized
    k_scale: jax.Array | None = None   # (B, P, page, KV) f32
    v_scale: jax.Array | None = None   # (B, P, page, KV) f32

    # ----------------------------------------------------------- derived
    @property
    def batch(self) -> int:
        return self.k.shape[0]

    @property
    def num_pages(self) -> int:
        return self.k.shape[1]

    @property
    def page_size(self) -> int:
        return self.k.shape[2]

    def valid_mask(self) -> jax.Array:
        """(B, P, page) bool — which cache slots hold live tokens."""
        return self.pos >= 0

    def tokens_per_page(self) -> jax.Array:
        """(B, P) int32 — live tokens in each page."""
        return jnp.sum(self.valid_mask(), axis=-1).astype(jnp.int32)

    def total_valid(self) -> jax.Array:
        """(B,) int32 — live tokens per request."""
        return jnp.sum(self.valid_mask(), axis=(1, 2)).astype(jnp.int32)

    def page_scores(self) -> jax.Array:
        """(B, P) f32 — mean token score per page (paper Alg. 1, block mode).
        Pages with no valid tokens score +inf (never the eviction argmin)."""
        valid = self.valid_mask()
        cnt = jnp.sum(valid, axis=-1)
        ssum = jnp.sum(jnp.where(valid, self.score, 0.0), axis=-1)
        return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def k_dequant(self) -> jax.Array:
        """K slab in f32/compute dtype (identity when not quantized)."""
        if not self.quantized:
            return self.k
        return self.k.astype(jnp.float32) * (self.k_scale / 127.0)[..., None]

    def v_dequant(self) -> jax.Array:
        if not self.quantized:
            return self.v
        return self.v.astype(jnp.float32) * (self.v_scale / 127.0)[..., None]


def quantize_absmax(x, axis: int = -1):
    """x: (..., hd) -> (int8 values, (...,) f32 absmax scales)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis)
    q = jnp.round(xf / jnp.maximum(scale, 1e-8)[..., None] * 127.0)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def init_layer_cache(batch: int, num_pages: int, page_size: int,
                     num_kv_heads: int, head_dim: int, dtype) -> PagedLayerCache:
    quantized = dtype in ("int8", jnp.int8)
    dt = jnp.int8 if quantized else dtype
    shape = (batch, num_pages, page_size, num_kv_heads, head_dim)
    sshape = (batch, num_pages, page_size, num_kv_heads)
    return PagedLayerCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        pos=jnp.full((batch, num_pages, page_size), -1, jnp.int32),
        score=jnp.full((batch, num_pages, page_size), -jnp.inf, jnp.float32),
        cur_page=jnp.zeros((batch,), jnp.int32),
        cur_off=jnp.zeros((batch,), jnp.int32),
        k_scale=jnp.zeros(sshape, jnp.float32) if quantized else None,
        v_scale=jnp.zeros(sshape, jnp.float32) if quantized else None,
    )


# ---------------------------------------------------------------------------
# writes
# ---------------------------------------------------------------------------

def write_token(cache: PagedLayerCache, k_tok, v_tok, pos_tok, score_tok,
                active=None) -> PagedLayerCache:
    """Append one token per request at the write head.

    k_tok, v_tok: (B, KV, hd); pos_tok: (B,) int32; score_tok: (B,) f32.
    ``active``: optional (B,) bool — requests not active are left untouched
    (continuous batching: finished / empty slots).
    Caller must ensure cur_off < page_size (policies roll the page over).
    """
    b = jnp.arange(cache.batch)
    if active is None:
        active = jnp.ones((cache.batch,), bool)
    p, o = cache.cur_page, cache.cur_off

    def upd(dst, val):
        cur = dst[b, p, o]
        return dst.at[b, p, o].set(jnp.where(
            active.reshape((-1,) + (1,) * (val.ndim - 1)), val.astype(dst.dtype), cur))

    if cache.quantized:
        kq, ks = quantize_absmax(k_tok)
        vq, vs = quantize_absmax(v_tok)
        k = upd(cache.k, kq)
        v = upd(cache.v, vq)
        cache = cache._replace(k_scale=upd(cache.k_scale, ks),
                               v_scale=upd(cache.v_scale, vs))
    else:
        k = upd(cache.k, k_tok)
        v = upd(cache.v, v_tok)
    pos = cache.pos.at[b, p, o].set(
        jnp.where(active, pos_tok.astype(jnp.int32), cache.pos[b, p, o]))
    score = cache.score.at[b, p, o].set(
        jnp.where(active, score_tok.astype(jnp.float32), cache.score[b, p, o]))
    off = jnp.where(active, o + 1, o)
    return cache._replace(k=k, v=v, pos=pos, score=score, cur_off=off)


def write_prompt_pages(cache: PagedLayerCache, k_sel, v_sel, pos_sel, score_sel,
                       ) -> PagedLayerCache:
    """Bulk-write C selected prompt tokens (already compressed by the prefill
    policy) into pages [0 .. C/page). C must be a multiple of page_size.

    k_sel, v_sel: (B, C, KV, hd); pos_sel: (B, C) (-1 = padding/invalid);
    score_sel: (B, C).
    """
    B, C = pos_sel.shape
    page = cache.page_size
    assert C % page == 0, (C, page)
    n = C // page
    assert n <= cache.num_pages, (n, cache.num_pages)
    KV, hd = k_sel.shape[2], k_sel.shape[3]

    if cache.quantized:
        kq, ks = quantize_absmax(k_sel)
        vq, vs = quantize_absmax(v_sel)
        k = cache.k.at[:, :n].set(kq.reshape(B, n, page, KV, hd))
        v = cache.v.at[:, :n].set(vq.reshape(B, n, page, KV, hd))
        cache = cache._replace(
            k_scale=cache.k_scale.at[:, :n].set(ks.reshape(B, n, page, KV)),
            v_scale=cache.v_scale.at[:, :n].set(vs.reshape(B, n, page, KV)))
    else:
        k = cache.k.at[:, :n].set(
            k_sel.reshape(B, n, page, KV, hd).astype(cache.k.dtype))
        v = cache.v.at[:, :n].set(
            v_sel.reshape(B, n, page, KV, hd).astype(cache.v.dtype))
    pos = cache.pos.at[:, :n].set(pos_sel.reshape(B, n, page).astype(jnp.int32))
    score = cache.score.at[:, :n].set(
        jnp.where(pos_sel.reshape(B, n, page) >= 0,
                  score_sel.reshape(B, n, page).astype(jnp.float32), -jnp.inf))
    return cache._replace(
        k=k, v=v, pos=pos, score=score,
        cur_page=jnp.full((B,), n, jnp.int32),
        cur_off=jnp.zeros((B,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# page-level operations (used by eviction policies)
# ---------------------------------------------------------------------------

def evict_page(cache: PagedLayerCache, page_idx, enable=None) -> PagedLayerCache:
    """Invalidate an entire page per request. page_idx: (B,) int32.
    ``enable``: (B,) bool — rows where eviction actually happens."""
    B = cache.batch
    b = jnp.arange(B)
    if enable is None:
        enable = jnp.ones((B,), bool)
    pos_rows = jnp.where(enable[:, None], -1, cache.pos[b, page_idx])
    score_rows = jnp.where(enable[:, None], -jnp.inf, cache.score[b, page_idx])
    return cache._replace(pos=cache.pos.at[b, page_idx].set(pos_rows),
                          score=cache.score.at[b, page_idx].set(score_rows))


def evict_token(cache: PagedLayerCache, flat_idx, enable=None) -> PagedLayerCache:
    """Invalidate a single token per request addressed by flattened (P*page)
    index. flat_idx: (B,) int32."""
    B, P, page = cache.pos.shape
    b = jnp.arange(B)
    if enable is None:
        enable = jnp.ones((B,), bool)
    pi, oi = flat_idx // page, flat_idx % page
    pos = cache.pos.at[b, pi, oi].set(
        jnp.where(enable, -1, cache.pos[b, pi, oi]))
    score = cache.score.at[b, pi, oi].set(
        jnp.where(enable, -jnp.inf, cache.score[b, pi, oi]))
    return cache._replace(pos=pos, score=score)


def find_free_page(cache: PagedLayerCache) -> tuple[jax.Array, jax.Array]:
    """(B,) index of a fully-empty page slot + (B,) bool whether one exists."""
    empty = cache.tokens_per_page() == 0                 # (B, P)
    idx = jnp.argmax(empty, axis=-1).astype(jnp.int32)
    exists = jnp.any(empty, axis=-1)
    return idx, exists


def start_new_page(cache: PagedLayerCache, slot, enable=None) -> PagedLayerCache:
    """Move the write head to ``slot`` (must be empty) and reset the offset."""
    if enable is None:
        enable = jnp.ones((cache.batch,), bool)
    return cache._replace(
        cur_page=jnp.where(enable, slot.astype(jnp.int32), cache.cur_page),
        cur_off=jnp.where(enable, 0, cache.cur_off),
    )


# ---------------------------------------------------------------------------
# gather to contiguous (tests / reference paths)
# ---------------------------------------------------------------------------

def to_contiguous(cache: PagedLayerCache):
    """Return (k, v, pos, mask) flattened over pages: (B, P*page, KV, hd),
    dequantized if needed. Order is physical, not logical — attention is
    permutation-invariant given correct positions, which tests exploit."""
    B, P, page, KV, hd = cache.k.shape
    return (cache.k_dequant().reshape(B, P * page, KV, hd),
            cache.v_dequant().reshape(B, P * page, KV, hd),
            cache.pos.reshape(B, P * page),
            cache.valid_mask().reshape(B, P * page))
