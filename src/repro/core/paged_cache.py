"""Functional paged KV cache — the TPU/JAX analogue of vLLM's block pool.

Layout (per attention layer; see DESIGN.md §2):
    k, v        : (N_pool, page, KV, hd)  ONE physical page pool shared by
                                          every request in the batch
    pos         : (N_pool, page) int32    original token position; -1 invalid
    score       : (N_pool, page) float32  per-token policy score (higher==keep)
    block_table : (B, P) int32            logical page -> physical pool page;
                                          -1 == unmapped slot
    ref_count   : (N_pool,) int32         pages mapped by a block table;
                                          0 == on the free list
    cur_page, cur_off : (B,) int32        write head (LOGICAL page slot, offset)

The free list is the ``ref_count == 0`` mask; :func:`alloc_pages` always
hands out the lowest-index free pages (deterministic, batch-safe — the i-th
allocating request gets the i-th free page). ``ref_count`` is a true count:
:func:`adopt_prefix` maps one physical page under SEVERAL block tables
(prefix sharing), so releasing a page means *decrementing* — the page's
data is only invalidated (and the page recycled) when the count reaches 0.
Every release path funnels through :func:`_unref_pages`, which enforces the
unmap-vs-free split and clamps at 0 so a double-release can never drive a
slot negative (and never clobbers a page some other table still maps).

Under an eviction policy with budget C and page size Bp, P is statically
``C/Bp + 1`` per request and ``N_pool = B * P`` by default — the budget makes
the working set a *static* shape, which is exactly what XLA wants (vLLM
needs a dynamic allocator for the same thing; see DESIGN.md §2). Unlike the
old per-request slab, a page evicted by one request returns to the SHARED
free list, so it is immediately available as headroom for any other request
— eviction is fleet-level memory reclamation, not per-request bookkeeping.

Evicting a page == zeroing its validity and pushing the physical page back
on the free list. No data movement, ever (the paper's point).

Invariants (tests/test_pool_invariants.py):
    F1  allocated + free == N_pool          (free-list conservation)
    F2  ref_count[p] == number of block-table entries mapping p (ACROSS all
        requests — shared prefix pages legitimately carry counts > 1)
    F3  no physical page is mapped twice by the SAME block table (cross-
        request double-mapping is exactly what prefix sharing is)
    F4  free pages hold no live tokens (their pos rows are all -1)

Sharing semantics (DESIGN.md §7): shared pages are always COMPLETE prompt
pages and are immutable — the write head never points at one (adopt_prefix
parks the head full so the next append rolls onto a fresh exclusive page).
Page-level eviction of a shared page is an unmap: the evicting request
drops its mapping and one reference; k/v/pos/score survive untouched for
every other mapper. Token-level eviction inside a shared page must
copy-on-write first (:func:`fork_page`) — the fork gives the mutating
request a private copy and releases one reference on the original.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import devstats


class PagedLayerCache(NamedTuple):
    k: jax.Array           # (N, page, KV, hd) — bf16/f32, or int8 (quantized)
    v: jax.Array           # (N, page, KV, hd)
    pos: jax.Array         # (N, page) int32, -1 invalid
    score: jax.Array       # (N, page) f32, -inf invalid
    block_table: jax.Array  # (B, P) int32, -1 unmapped
    ref_count: jax.Array   # (N,) int32, 0 == free
    cur_page: jax.Array    # (B,) int32 — logical page slot
    cur_off: jax.Array     # (B,) int32
    # int8 mode (beyond-paper: the quantized-KV composition the paper cites
    # as future work): absmax scale per (token, head); None when not quantized
    k_scale: jax.Array | None = None   # (N, page, KV) f32
    v_scale: jax.Array | None = None   # (N, page, KV) f32
    # telemetry (repro.core.devstats / DESIGN.md §9): per-step event counts
    # accumulated by the pool mutators as pure jnp scatter-adds. None == off
    # (a static Python value, so the disabled path traces unchanged HLO).
    stats: jax.Array | None = None     # (devstats.NSTATS,) int32

    # ----------------------------------------------------------- derived
    @property
    def batch(self) -> int:
        return self.block_table.shape[0]

    @property
    def num_pages(self) -> int:
        """Logical pages per request (block-table width)."""
        return self.block_table.shape[1]

    @property
    def pool_pages(self) -> int:
        """Physical pages in the shared pool."""
        return self.k.shape[0]

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    # -------------------------------------------------- block-table views
    def mapped_mask(self) -> jax.Array:
        """(B, P) bool — which logical slots hold a physical page."""
        return self.block_table >= 0

    def _phys(self) -> jax.Array:
        """(B, P) int32 — physical ids, clamped to 0 where unmapped."""
        return jnp.maximum(self.block_table, 0)

    def gather_pages(self, pool_arr: jax.Array) -> jax.Array:
        """Gather (N, page, ...) pool data into per-request (B, P, page, ...)
        layout through the block table. Unmapped slots carry page 0's data —
        callers must mask with :meth:`mapped_mask` / :meth:`pos_view`."""
        return jnp.take(pool_arr, self._phys(), axis=0)

    def pos_view(self) -> jax.Array:
        """(B, P, page) int32 — per-request positions; -1 where unmapped."""
        return jnp.where(self.mapped_mask()[..., None],
                         self.gather_pages(self.pos), -1)

    def score_view(self) -> jax.Array:
        """(B, P, page) f32 — per-request scores; -inf where unmapped."""
        return jnp.where(self.mapped_mask()[..., None],
                         self.gather_pages(self.score), -jnp.inf)

    def k_view(self) -> jax.Array:
        """(B, P, page, KV, hd) dequantized per-request K (garbage where
        unmapped — mask with valid_mask())."""
        return self.gather_pages(self.k_dequant())

    def v_view(self) -> jax.Array:
        return self.gather_pages(self.v_dequant())

    # ----------------------------------------------------- token accounting
    def valid_mask(self) -> jax.Array:
        """(B, P, page) bool — which cache slots hold live tokens."""
        return self.pos_view() >= 0

    def tokens_per_page(self) -> jax.Array:
        """(B, P) int32 — live tokens in each logical page."""
        return jnp.sum(self.valid_mask(), axis=-1).astype(jnp.int32)

    def total_valid(self) -> jax.Array:
        """(B,) int32 — live tokens per request."""
        return jnp.sum(self.valid_mask(), axis=(1, 2)).astype(jnp.int32)

    def page_scores(self) -> jax.Array:
        """(B, P) f32 — mean token score per page (paper Alg. 1, block mode).
        Pages with no valid tokens score +inf (never the eviction argmin).

        This is the STORED-score reduction (write-time scores). On the
        Pallas hot paths the attention kernels emit the same reduction as a
        fused epilogue (DESIGN.md §8) and the policies take it via their
        ``page_scores=`` argument, skipping this read entirely."""
        valid = self.valid_mask()
        cnt = jnp.sum(valid, axis=-1)
        ssum = jnp.sum(jnp.where(valid, self.score_view(), 0.0), axis=-1)
        return jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1), jnp.inf)

    # --------------------------------------------------------- free list
    def free_mask(self) -> jax.Array:
        """(N,) bool — pages on the free list."""
        return self.ref_count == 0

    def num_free(self) -> jax.Array:
        """() int32 — pages currently on the free list (fleet headroom)."""
        return jnp.sum(self.free_mask()).astype(jnp.int32)

    # ------------------------------------------------------- quantization
    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def k_dequant(self) -> jax.Array:
        """K pool in f32/compute dtype (identity when not quantized)."""
        if not self.quantized:
            return self.k
        return self.k.astype(jnp.float32) * (self.k_scale / 127.0)[..., None]

    def v_dequant(self) -> jax.Array:
        if not self.quantized:
            return self.v
        return self.v.astype(jnp.float32) * (self.v_scale / 127.0)[..., None]


def quantize_absmax(x, axis: int = -1):
    """x: (..., hd) -> (int8 values, (...,) f32 absmax scales)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis)
    q = jnp.round(xf / jnp.maximum(scale, 1e-8)[..., None] * 127.0)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def init_layer_cache(batch: int, num_pages: int, page_size: int,
                     num_kv_heads: int, head_dim: int, dtype,
                     pool_pages: int | None = None,
                     track_stats: bool = False) -> PagedLayerCache:
    """Empty cache: pool of ``pool_pages`` (default batch*num_pages) physical
    pages, per-request block tables of ``num_pages`` logical slots.

    Logical slot 0 of request b is pre-mapped to physical page b so the write
    head always points at a mapped page (the working page).

    ``track_stats`` attaches the (devstats.NSTATS,) int32 telemetry vector;
    the pool mutators then accumulate event counts into it (DESIGN.md §9).
    Off by default: raw-core callers see the exact pre-telemetry pytree."""
    N = pool_pages if pool_pages is not None else batch * num_pages
    assert N >= batch, (N, batch)
    quantized = dtype in ("int8", jnp.int8)
    dt = jnp.int8 if quantized else dtype
    shape = (N, page_size, num_kv_heads, head_dim)
    sshape = (N, page_size, num_kv_heads)
    bt = jnp.full((batch, num_pages), -1, jnp.int32)
    bt = bt.at[:, 0].set(jnp.arange(batch, dtype=jnp.int32))
    ref = jnp.zeros((N,), jnp.int32).at[:batch].set(1)
    return PagedLayerCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        pos=jnp.full((N, page_size), -1, jnp.int32),
        score=jnp.full((N, page_size), -jnp.inf, jnp.float32),
        block_table=bt,
        ref_count=ref,
        cur_page=jnp.zeros((batch,), jnp.int32),
        cur_off=jnp.zeros((batch,), jnp.int32),
        k_scale=jnp.zeros(sshape, jnp.float32) if quantized else None,
        v_scale=jnp.zeros(sshape, jnp.float32) if quantized else None,
        stats=devstats.zeros() if track_stats else None,
    )


# ---------------------------------------------------------------------------
# free-list allocator
# ---------------------------------------------------------------------------
# Scatter targets use the pool size N as an out-of-bounds sentinel: JAX drops
# out-of-bounds scatter updates, which makes every batched op below mask-free
# (no where-with-old-value dance, no duplicate-index hazards).

def alloc_pages(cache: PagedLayerCache, need):
    """Pop one free physical page per request where ``need``.

    need: (B,) bool. Returns (cache', phys (B,) int32, ok (B,) bool); ``phys``
    is the pool sentinel N where not ok. The i-th needing request receives the
    i-th lowest-index free page, so simultaneous allocations never collide.
    O(N) via a cumsum + searchsorted over the free mask (no pool sort)."""
    N = cache.pool_pages
    free = cache.free_mask()                          # (N,)
    csum = jnp.cumsum(free.astype(jnp.int32))         # free pages seen so far
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1     # (B,) alloc position
    ok = need & (rank < csum[-1])
    # index of the (rank+1)-th free page
    found = jnp.searchsorted(csum, rank + 1, side="left")
    phys = jnp.where(ok, found, N).astype(jnp.int32)
    ref = cache.ref_count.at[phys].add(1)             # OOB sentinel dropped
    return cache._replace(
        ref_count=ref,
        stats=devstats.bump(cache.stats, devstats.PAGES_ALLOCATED, ok),
    ), phys, ok


def _unref_pages(cache: PagedLayerCache, tgt) -> PagedLayerCache:
    """Release one reference per entry of ``tgt`` (flattened physical ids;
    the pool size N is the masked-out sentinel). The single funnel for EVERY
    release path, enforcing the unmap-vs-free split:

    - ref_count decrements are clamped at 0 — a double-release (the latent
      underflow at the old ``add(-1)`` sites) can never drive a slot
      negative and thereby fake an allocated page.
    - pos/score are invalidated ONLY for pages whose count reaches 0. A page
      some other block table still maps (ref stays > 0 — a shared prefix
      page) keeps its k/v/pos/score intact: releasing is unmapping, never
      data destruction, so :func:`alloc_pages` (free == ref_count 0) can
      never recycle a page whose refcount is still positive.

    Duplicate targets (several rows releasing the same shared page in one
    batched op) accumulate correctly via scatter-add."""
    N = cache.pool_pages
    dec = jnp.zeros((N + 1,), jnp.int32).at[tgt].add(1)[:N]
    new_ref = jnp.maximum(cache.ref_count - dec, 0)
    newly_free = (dec > 0) & (cache.ref_count > 0) & (new_ref == 0)
    # RELEASED counts the decrements that actually landed (the clamp means
    # dec > ref is over-asking), so Δ sum(ref_count) reconciles exactly
    stats = devstats.bump(cache.stats, devstats.PAGES_RELEASED,
                          jnp.minimum(dec, cache.ref_count))
    stats = devstats.bump(stats, devstats.PAGES_FREED, newly_free)
    return cache._replace(
        pos=jnp.where(newly_free[:, None], -1, cache.pos),
        score=jnp.where(newly_free[:, None], -jnp.inf, cache.score),
        ref_count=new_ref,
        stats=stats,
    )


def _free_phys(cache: PagedLayerCache, phys, enable) -> PagedLayerCache:
    """Release one reference on (B,) physical pages where ``enable``; data is
    invalidated only if the page's count reaches 0 (see _unref_pages)."""
    return _unref_pages(cache, jnp.where(enable, phys, cache.pool_pages))


def find_free_slot(cache: PagedLayerCache):
    """(B,) first UNMAPPED logical slot per request + (B,) bool existence."""
    unmapped = ~cache.mapped_mask()                   # (B, P)
    idx = jnp.argmax(unmapped, axis=-1).astype(jnp.int32)
    exists = jnp.any(unmapped, axis=-1)
    return idx, exists


def start_new_page(cache: PagedLayerCache, slot, phys, enable=None
                   ) -> PagedLayerCache:
    """Map logical ``slot`` -> physical ``phys`` (freshly allocated via
    :func:`alloc_pages`) and move the write head there."""
    B = cache.batch
    b = jnp.arange(B)
    if enable is None:
        enable = jnp.ones((B,), bool)
    bt = cache.block_table.at[b, slot].set(
        jnp.where(enable, phys.astype(jnp.int32), cache.block_table[b, slot]))
    return cache._replace(
        block_table=bt,
        cur_page=jnp.where(enable, slot.astype(jnp.int32), cache.cur_page),
        cur_off=jnp.where(enable, 0, cache.cur_off),
    )


def reclaim_empty_pages(cache: PagedLayerCache, include_current=None
                        ) -> PagedLayerCache:
    """Unmap every logical slot whose page holds zero live tokens and return
    the physical page to the shared free list. The current write page is
    exempt unless ``include_current`` (B,) bool says the row is rolling over
    anyway. Empty mapped pages arise from token-level eviction (unstructured
    baselines) and from evicting the just-filled working page."""
    B, P = cache.block_table.shape
    N = cache.pool_pages
    if include_current is None:
        include_current = jnp.zeros((B,), bool)
    is_cur = jax.nn.one_hot(cache.cur_page, P, dtype=bool)
    dead = cache.mapped_mask() & (cache.tokens_per_page() == 0) & \
        (~is_cur | include_current[:, None])          # (B, P)
    # empty pages already hold pos == -1 everywhere (F4): freeing is just
    # a clamped ref_count decrement + block-table unmap
    tgt = jnp.where(dead, cache._phys(), N).reshape(-1)
    cache = _unref_pages(cache, tgt)
    return cache._replace(block_table=jnp.where(dead, -1, cache.block_table))


# ---------------------------------------------------------------------------
# writes
# ---------------------------------------------------------------------------

def write_token(cache: PagedLayerCache, k_tok, v_tok, pos_tok, score_tok,
                active=None) -> PagedLayerCache:
    """Append one token per request at the write head.

    k_tok, v_tok: (B, KV, hd); pos_tok: (B,) int32; score_tok: (B,) f32.
    ``active``: optional (B,) bool — requests not active are left untouched
    (continuous batching: finished / empty slots).
    Caller must ensure cur_off < page_size (policies roll the page over)."""
    B = cache.batch
    b = jnp.arange(B)
    N = cache.pool_pages
    if active is None:
        active = jnp.ones((B,), bool)
    phys = cache.block_table[b, cache.cur_page]       # (B,) physical page
    ok = active & (phys >= 0)
    tgt = jnp.where(ok, phys, N)                      # OOB drop when masked
    o = cache.cur_off

    def upd(dst, val):
        return dst.at[tgt, o].set(val.astype(dst.dtype))

    if cache.quantized:
        kq, ks = quantize_absmax(k_tok)
        vq, vs = quantize_absmax(v_tok)
        k = upd(cache.k, kq)
        v = upd(cache.v, vq)
        cache = cache._replace(k_scale=upd(cache.k_scale, ks),
                               v_scale=upd(cache.v_scale, vs))
    else:
        k = upd(cache.k, k_tok)
        v = upd(cache.v, v_tok)
    pos = cache.pos.at[tgt, o].set(pos_tok.astype(jnp.int32))
    score = cache.score.at[tgt, o].set(score_tok.astype(jnp.float32))
    off = jnp.where(ok, o + 1, o)
    return cache._replace(
        k=k, v=v, pos=pos, score=score, cur_off=off,
        stats=devstats.bump(cache.stats, devstats.TOKENS_WRITTEN, ok))


def write_prompt_pages(cache: PagedLayerCache, k_sel, v_sel, pos_sel, score_sel,
                       ) -> PagedLayerCache:
    """Bulk-write C selected prompt tokens (already compressed by the prefill
    policy) into logical pages [0 .. C/page). C must be a multiple of
    page_size. RESETS the whole cache: every request row is rewritten, all
    previous mappings are discarded. Being a wholesale reset it does NOT
    emit devstats events (the conservation identities of DESIGN.md §9 hold
    across the incremental mutators only; the engine's unified step never
    calls this — it is the offline/bench path).

    Physical placement is row-major over the first B*(n+1) pool pages —
    deterministic, so prefill results are bit-stable regardless of what the
    pool held before. One extra page per request is mapped (and left empty)
    as the decode working page wherever the block table has room.

    k_sel, v_sel: (B, C, KV, hd); pos_sel: (B, C) (-1 = padding/invalid);
    score_sel: (B, C)."""
    B, C = pos_sel.shape
    page = cache.page_size
    P = cache.num_pages
    N = cache.pool_pages
    assert C % page == 0, (C, page)
    n = C // page
    assert n <= P, (n, P)
    KV, hd = k_sel.shape[2], k_sel.shape[3]
    # map an empty working page after the prompt pages when a slot exists;
    # when the prompt exactly fills the block table, park the head on the
    # last page with cur_off == page_size (writes drop until rollover)
    extra = 1 if n < P else 0
    stride = n + extra
    assert B * stride <= N, (B, stride, N)

    phys = (jnp.arange(B, dtype=jnp.int32)[:, None] * stride +
            jnp.arange(stride, dtype=jnp.int32)[None, :])      # (B, stride)
    bt = jnp.full((B, P), -1, jnp.int32)
    bt = lax.dynamic_update_slice(bt, phys, (0, 0))
    ref = jnp.zeros((N,), jnp.int32).at[phys.reshape(-1)].set(1)

    def scatter_prompt(reset_pool, val):
        """Write the (B*n, ...) prompt pages into the freshly-reset pool at
        rows b*stride + j."""
        idx = (jnp.arange(B, dtype=jnp.int32)[:, None] * stride +
               jnp.arange(n, dtype=jnp.int32)[None, :]).reshape(-1)
        return reset_pool.at[idx].set(val.astype(reset_pool.dtype))

    if cache.quantized:
        kq, ks = quantize_absmax(k_sel)
        vq, vs = quantize_absmax(v_sel)
        k = scatter_prompt(jnp.zeros_like(cache.k),
                           kq.reshape(B * n, page, KV, hd))
        v = scatter_prompt(jnp.zeros_like(cache.v),
                           vq.reshape(B * n, page, KV, hd))
        cache = cache._replace(
            k_scale=scatter_prompt(jnp.zeros_like(cache.k_scale),
                                   ks.reshape(B * n, page, KV)),
            v_scale=scatter_prompt(jnp.zeros_like(cache.v_scale),
                                   vs.reshape(B * n, page, KV)))
    else:
        k = scatter_prompt(jnp.zeros_like(cache.k),
                           k_sel.reshape(B * n, page, KV, hd))
        v = scatter_prompt(jnp.zeros_like(cache.v),
                           v_sel.reshape(B * n, page, KV, hd))
    pos_pages = pos_sel.reshape(B * n, page).astype(jnp.int32)
    score_pages = jnp.where(pos_sel.reshape(B * n, page) >= 0,
                            score_sel.reshape(B * n, page).astype(jnp.float32),
                            -jnp.inf)
    pos = scatter_prompt(jnp.full_like(cache.pos, -1), pos_pages)
    score = scatter_prompt(jnp.full_like(cache.score, -jnp.inf), score_pages)
    return cache._replace(
        k=k, v=v, pos=pos, score=score, block_table=bt, ref_count=ref,
        cur_page=jnp.full((B,), min(n, P - 1), jnp.int32),
        cur_off=jnp.full((B,), 0 if extra else page, jnp.int32),
    )


# ---------------------------------------------------------------------------
# page-level operations (used by eviction policies)
# ---------------------------------------------------------------------------

def evict_page(cache: PagedLayerCache, page_idx, enable=None) -> PagedLayerCache:
    """Evict an entire LOGICAL page per request: invalidate its tokens,
    return the physical page to the shared free list, unmap the slot.
    page_idx: (B,) int32 logical slot. ``enable``: (B,) bool."""
    B = cache.batch
    b = jnp.arange(B)
    if enable is None:
        enable = jnp.ones((B,), bool)
    phys = cache.block_table[b, page_idx]             # (B,)
    en = enable & (phys >= 0)
    cache = _free_phys(cache, jnp.maximum(phys, 0), en)
    bt = cache.block_table.at[b, page_idx].set(
        jnp.where(en, -1, cache.block_table[b, page_idx]))
    return cache._replace(
        block_table=bt,
        stats=devstats.bump(cache.stats, devstats.PAGES_EVICTED, en))


def fork_page(cache: PagedLayerCache, slot, enable=None):
    """Copy-on-write fork: where ``enable`` and the physical page mapped at
    logical ``slot`` is SHARED (ref_count > 1), copy its k/v/pos/score (and
    int8 scales) onto a freshly allocated pool page, remap this row's slot to
    the copy, and release one reference on the original. Rows whose page is
    exclusive or unmapped are untouched (fork is the identity there).

    slot: (B,) int32 logical slots. Returns (cache, forked (B,) bool).
    If the pool is dry the fork silently does not happen (forked stays
    False) — callers must then skip their mutation of that row, because the
    un-forked page is another request's live data. Two rows forking the same
    source page in one call each get their own copy; if every mapper forks
    away, the source's count reaches 0 and it returns to the free list."""
    B = cache.batch
    b = jnp.arange(B)
    N = cache.pool_pages
    if enable is None:
        enable = jnp.ones((B,), bool)
    phys = cache.block_table[b, slot]                     # (B,)
    src = jnp.maximum(phys, 0)
    need = enable & (phys >= 0) & (cache.ref_count[src] > 1)
    cache, newp, ok = alloc_pages(cache, need)
    do = need & ok
    tgt = jnp.where(do, newp, N)                          # OOB drop when masked

    def cp(arr):
        return arr.at[tgt].set(arr[src])

    cache = cache._replace(
        k=cp(cache.k), v=cp(cache.v), pos=cp(cache.pos), score=cp(cache.score),
        k_scale=cp(cache.k_scale) if cache.quantized else None,
        v_scale=cp(cache.v_scale) if cache.quantized else None,
        block_table=cache.block_table.at[b, slot].set(
            jnp.where(do, newp.astype(jnp.int32), phys)),
        stats=devstats.bump(cache.stats, devstats.PAGES_FORKED, do),
    )
    # release one reference on the source (was > 1, so this never invalidates
    # unless EVERY mapper forked away in this very call — then it frees)
    return _unref_pages(cache, jnp.where(do, src, N)), do


def _shared_slots(cache: PagedLayerCache) -> jax.Array:
    """(B, P) bool — logical slots whose physical page is mapped by more
    than one block-table entry."""
    return cache.mapped_mask() & (cache.ref_count[cache._phys()] > 1)


def _cow_slots_mask(cache: PagedLayerCache, slot_mask) -> PagedLayerCache:
    """CoW barrier token-level mutation paths run before writing: for each
    row, fork the FIRST (row, slot) in the (B, P) bool mask whose page is
    shared. At most one fork per row per call keeps the decode-step graph
    small; remaining shared slots stay un-forked this round and their
    mutation is skipped by the callers' exclusive-page gate, then forked on
    the next step's barrier — lazy CoW, same invariants, budget transiently
    exceeded at worst. Runs unconditionally (fork_page is the identity when
    nothing targeted is shared): a data-dependent cond here would re-trace
    its branches on every eager call, and under jit XLA pays the small fork
    graph either way."""
    hit = slot_mask & _shared_slots(cache)                # (B, P)
    slot = jnp.argmax(hit, axis=-1).astype(jnp.int32)     # first shared slot
    cache, _ = fork_page(cache, slot, enable=jnp.any(hit, axis=-1))
    return cache


def evict_token(cache: PagedLayerCache, flat_idx, enable=None) -> PagedLayerCache:
    """Invalidate a single token per request addressed by flattened LOGICAL
    (P*page) index. flat_idx: (B,) int32. The physical page stays mapped
    (unstructured fragmentation — the paper's Limitation 1); fully-emptied
    pages return to the pool at the next rollover via reclaim_empty_pages.

    Mutating a SHARED page would corrupt the sharer's view, so the page is
    CoW-forked first; if the fork is starved (pool dry) the eviction is
    skipped this round — the budget is transiently exceeded rather than
    another request's cache corrupted."""
    B = cache.batch
    page = cache.page_size
    N = cache.pool_pages
    b = jnp.arange(B)
    if enable is None:
        enable = jnp.ones((B,), bool)
    pi, oi = flat_idx // page, flat_idx % page
    cache, _ = fork_page(cache, pi, enable=enable)
    phys = cache.block_table[b, pi]
    en = enable & (phys >= 0) & (cache.ref_count[jnp.maximum(phys, 0)] <= 1)
    tgt = jnp.where(en, jnp.maximum(phys, 0), N)
    # count only evictions that invalidated a LIVE token (clamped read of
    # row N-1 for masked rows is harmless — en gates it out)
    live = en & (cache.pos[jnp.minimum(tgt, N - 1), oi] >= 0)
    return cache._replace(
        pos=cache.pos.at[tgt, oi].set(-1),
        score=cache.score.at[tgt, oi].set(-jnp.inf),
        stats=devstats.bump(cache.stats, devstats.TOKENS_EVICTED, live),
    )


# ---------------------------------------------------------------------------
# chunked append (prefill writes straight into the shared pool)
# ---------------------------------------------------------------------------
# The old continuous-batching path prefilled a request into a private B=1
# pool and spliced it into the batch (``insert_request``). That splice — and
# its per-slot-specialized compiled program — is gone: requests now prefill
# in place, chunk by chunk, through the same block tables decode uses.

def release_rows(cache: PagedLayerCache, enable) -> PagedLayerCache:
    """Free EVERY page the selected batch rows map (request retired — its
    slot is being handed to a new request) and reset their write heads.
    ``enable``: (B,) bool. Runs inside the unified step for rows that start
    prefilling this step, so the leaving request's pages return to the
    SHARED free list before the newcomer's first chunk allocates. Pages the
    retiring row shared with a still-resident request only lose one
    reference — their data stays live for the sharer (_unref_pages)."""
    B, P = cache.block_table.shape
    N = cache.pool_pages
    dead = cache.mapped_mask() & enable[:, None]          # (B, P)
    tgt = jnp.where(dead, cache._phys(), N).reshape(-1)
    cache = _unref_pages(cache, tgt)
    return cache._replace(
        block_table=jnp.where(dead, -1, cache.block_table),
        cur_page=jnp.where(enable, 0, cache.cur_page),
        # park the head "full" on the unmapped slot: the first append's lazy
        # rollover then allocates the row's first page from the free list
        cur_off=jnp.where(enable, cache.page_size, cache.cur_off),
    )


def adopt_prefix(cache: PagedLayerCache, src, n_pages, enable=None
                 ) -> PagedLayerCache:
    """Map the first ``n_pages`` logical slots of row ``src`` into each
    enabled row's block table, bumping the shared pages' ref counts — the
    device half of prefix sharing (the host half is the scheduler's radix
    lookup plus the engine's intactness probe; DESIGN.md §7).

    src: (B,) int32 source batch row (-1 == no sharing); n_pages: (B,) int32.
    Preconditions the caller (forward_step's reset path) guarantees:
    the enabled row was just released (empty block table), ``src`` is a
    live, different row, and its first ``n_pages`` slots are mapped FULL
    pages holding the contiguous token prefix [0, n_pages*page_size) — the
    engine probes exactly this before scheduling the adoption.

    The write head parks FULL on the last adopted slot, so the adopting
    row's first appended token lazily rolls onto a fresh exclusive page:
    shared pages are never written, only read — and unmapped or CoW-forked
    by the eviction paths."""
    B, P = cache.block_table.shape
    N = cache.pool_pages
    if enable is None:
        enable = jnp.ones((B,), bool)
    en = enable & (src >= 0) & (n_pages > 0)
    src_bt = cache.block_table[jnp.maximum(src, 0)]       # (B, P) source rows
    take = en[:, None] & (jnp.arange(P)[None, :] < n_pages[:, None]) & \
        (src_bt >= 0)
    bt = jnp.where(take, src_bt, cache.block_table)
    tgt = jnp.where(take, jnp.maximum(src_bt, 0), N).reshape(-1)
    return cache._replace(
        block_table=bt,
        ref_count=cache.ref_count.at[tgt].add(1),
        stats=devstats.bump(cache.stats, devstats.PAGES_ADOPTED, take),
        cur_page=jnp.where(en, jnp.maximum(n_pages - 1, 0).astype(jnp.int32),
                           cache.cur_page),
        cur_off=jnp.where(en, cache.page_size, cache.cur_off),
    )


def rollover_to_free_page(cache: PagedLayerCache, need):
    """Where ``need``, move the write head onto a fresh physical page:
    reclaim fully-emptied mapped pages, pick the first unmapped logical
    slot, pop a free pool page, map it. If a row has no unmapped slot or
    the pool is dry, force-evict that row's fewest-token (but > 0) page —
    never the current write page — which releases both a slot and a
    physical page, so the next write ALWAYS lands. Returns
    (cache, must_force (B,) bool). Shared by decode post_write rollover
    (`policies._rollover_to_free_page`, which reports the telemetry) and
    the chunked-append path."""
    c = reclaim_empty_pages(cache, include_current=need)
    slot, slot_ok = find_free_slot(c)
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    phys_ok = rank < c.num_free()
    must_force = need & (~slot_ok | ~phys_ok)
    tpp = c.tokens_per_page().astype(jnp.float32)         # (B, P)
    B, P = tpp.shape
    cur_onehot = jax.nn.one_hot(c.cur_page, P, dtype=bool)
    # prefer EXCLUSIVE pages as force-victims: unmapping a shared page frees
    # a logical slot but no physical page (the sharer keeps it), so it only
    # helps when no exclusively-owned candidate exists at all
    shared_penalty = jnp.where(_shared_slots(c), 1e6, 0.0)
    cand = jnp.where((tpp > 0) & ~cur_onehot, tpp + shared_penalty, jnp.inf)
    victim = jnp.argmin(cand, axis=-1).astype(jnp.int32)
    c = c._replace(stats=devstats.bump(c.stats, devstats.FORCED_EVICTIONS,
                                       must_force))
    c = evict_page(c, victim, enable=must_force)
    slot2, _ = find_free_slot(c)
    slot = jnp.where(must_force, slot2, slot)
    c, phys, ok = alloc_pages(c, need)
    return start_new_page(c, slot, phys, enable=need & ok), must_force


def _chunk_roll_noop(args):
    return args[0]


def _chunk_roll_body(args):
    cache, need = args
    return rollover_to_free_page(cache, need)[0]


def chunk_rollover(cache: PagedLayerCache, need) -> PagedLayerCache:
    """Where ``need``, move the write head onto a fresh physical page from
    the SHARED free list (reclaiming fully-emptied mapped pages first).
    Chunked prefill sizes block tables with ``ceil(chunk/page)`` slots of
    headroom (``transformer.init_decode_caches``), so structured policies
    never run dry mid-chunk; unstructured token policies CAN (their top-C
    survivors scatter one-per-page), in which case the fewest-token page is
    force-evicted so the incoming tokens always land."""
    return lax.cond(jnp.any(need), _chunk_roll_body, _chunk_roll_noop,
                    (cache, need))


def append_chunk(cache: PagedLayerCache, k_chunk, v_chunk, pos_chunk,
                 score_chunk, n_tok) -> PagedLayerCache:
    """Append up to T tokens per request at the write head, allocating fresh
    pages from the shared free list as pages fill.

    k_chunk, v_chunk : (B, T, KV, hd)
    pos_chunk        : (B, T) int32, -1 for padding past ``n_tok``
    score_chunk      : (B, T) f32 policy write scores
    n_tok            : (B,) int32 — row b appends tokens [0, n_tok[b])

    NO eviction happens mid-chunk: the policy compresses at the chunk
    boundary (``EvictionPolicy.chunk_prefill_evict`` — the incremental form
    of the paper's Alg. 2), so a row transiently holds up to
    budget + chunk tokens. A decode row is just the T == 1 (or n_tok == 1)
    case of the same op — the unified step program has no separate insert
    or prefill write path."""
    B, T = pos_chunk.shape

    def body(c, xs):
        k_t, v_t, p_t, s_t, t = xs
        act = t < n_tok
        c = chunk_rollover(c, act & (c.cur_off >= c.page_size))
        return write_token(c, k_t, v_t, p_t, s_t, active=act), None

    xs = (jnp.swapaxes(k_chunk, 0, 1), jnp.swapaxes(v_chunk, 0, 1),
          pos_chunk.T, score_chunk.T, jnp.arange(T))
    cache, _ = lax.scan(body, cache, xs)
    return cache


# ---------------------------------------------------------------------------
# masked bulk eviction (chunk-boundary compression)
# ---------------------------------------------------------------------------

def evict_token_mask(cache: PagedLayerCache, mask) -> PagedLayerCache:
    """Invalidate every token selected by a LOGICAL (B, P, page) bool mask.
    Physical pages stay mapped; fully-emptied pages return to the pool via
    :func:`reclaim_empty_pages` (the chunk hook calls it after this).

    Slots whose page is SHARED are CoW-forked before the write (the sharer's
    view must not change); a slot whose fork was starved by a dry pool is
    skipped — budget transiently exceeded, never cross-request corruption."""
    B, P, page = mask.shape
    N = cache.pool_pages
    cache = _cow_slots_mask(cache, jnp.any(mask, axis=-1))
    phys = jnp.broadcast_to(cache._phys()[..., None], (B, P, page))
    exclusive = cache.ref_count[cache._phys()] <= 1       # (B, P)
    en = mask & (cache.mapped_mask() & exclusive)[..., None]
    tgt = jnp.where(en, phys, N).reshape(-1)
    off = jnp.broadcast_to(jnp.arange(page, dtype=jnp.int32), (B, P, page)
                           ).reshape(-1)
    live = en & (cache.pos_view() >= 0)   # only live slots count as evicted
    return cache._replace(
        pos=cache.pos.at[tgt, off].set(-1),
        score=cache.score.at[tgt, off].set(-jnp.inf),
        stats=devstats.bump(cache.stats, devstats.TOKENS_EVICTED, live),
    )


def evict_pages_mask(cache: PagedLayerCache, mask) -> PagedLayerCache:
    """Evict every LOGICAL page selected by a (B, P) bool mask: unmap the
    slot and release one reference; tokens are invalidated (and the physical
    page returns to the shared free list) only when no other block table
    still maps the page. The multi-victim form of :func:`evict_page` — chunk
    boundaries can owe up to ceil(chunk/page) evictions at once. Evicting a
    SHARED prefix page is therefore purely local: the evicting request's
    view shrinks (valid_mask follows mapped_mask), the sharer's view is
    untouched."""
    N = cache.pool_pages
    en = mask & cache.mapped_mask()                       # (B, P)
    tgt = jnp.where(en, cache._phys(), N).reshape(-1)
    cache = _unref_pages(cache, tgt)
    return cache._replace(
        block_table=jnp.where(en, -1, cache.block_table),
        stats=devstats.bump(cache.stats, devstats.PAGES_EVICTED, en))


def row_intact_prefix_pages(cache: PagedLayerCache, row) -> jax.Array:
    """() int32 — length of the leading run of batch row ``row``'s logical
    slots that hold COMPLETE, position-contiguous prompt pages (slot i holds
    exactly positions [i*page, (i+1)*page)). This is what makes a prefix
    adoptable: eviction may have punched holes in the owner's prefix (or a
    windowed layer shed it), and a partially-written working page never
    qualifies. Capped at P-1 so an adopting row always keeps an unmapped
    slot for its own working page. The engine's prefix-sharing probe takes
    the min of this over every attention layer (transformer.intact_prefix_pages)."""
    P = cache.num_pages
    page = cache.page_size
    bt = cache.block_table[row]                           # (P,)
    pos = cache.pos[jnp.maximum(bt, 0)]                   # (P, page)
    want = (jnp.arange(P, dtype=jnp.int32)[:, None] * page +
            jnp.arange(page, dtype=jnp.int32)[None, :])
    ok = (bt >= 0) & jnp.all(pos == want, axis=-1)
    run = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))
    return jnp.minimum(run, P - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# gather to contiguous (tests / reference paths)
# ---------------------------------------------------------------------------

def to_contiguous(cache: PagedLayerCache):
    """Return (k, v, pos, mask) flattened over logical pages:
    (B, P*page, KV, hd), dequantized if needed. Order is physical-within-
    logical, not position order — attention is permutation-invariant given
    correct positions, which tests exploit."""
    B, P, page = cache.batch, cache.num_pages, cache.page_size
    KV, hd = cache.k.shape[2], cache.k.shape[3]
    return (cache.k_view().reshape(B, P * page, KV, hd),
            cache.v_view().reshape(B, P * page, KV, hd),
            cache.pos_view().reshape(B, P * page),
            cache.valid_mask().reshape(B, P * page))


# ---------------------------------------------------------------------------
# forensics view (obs/lineage.py)
# ---------------------------------------------------------------------------

def lineage_snapshot(cache: PagedLayerCache) -> dict:
    """Pure-jnp forensics view of one layer's pool, jitted by the engine and
    pulled to host once per step when the lineage ledger is on. The ledger
    diffs consecutive snapshots (plus the step plan) into alloc / adopt /
    fork / evict / release events and reconciles its replayed state against
    ``block_table`` / ``ref_count`` exactly (DESIGN.md §10).

    ``page_scores`` is the PRE-mutation policy ranking from the *previous*
    step's snapshot that prices an eviction observed this step — the ledger
    reads scores from ``prev``, never ``cur``."""
    return {
        "block_table": cache.block_table,            # (B, P) int32
        "ref_count": cache.ref_count,                # (N,) int32
        "cur_page": cache.cur_page,                  # (B,) int32 working lpi
        "tokens_per_page": cache.tokens_per_page(),  # (B, P) int32
        "page_scores": cache.page_scores(),          # (B, P) f32, inf=empty
        "pos_base": jnp.where(                       # (B, P) int32, -1=empty
            cache.tokens_per_page() > 0,
            jnp.min(jnp.where(cache.valid_mask(), cache.pos_view(),
                              jnp.iinfo(jnp.int32).max), axis=-1),
            -1).astype(jnp.int32),
    }
