"""Paper Algorithm 2 — prefill-phase token compression (one-shot form).

After the prompt forward pass produces contiguous K/V for a layer, the
policy selects which tokens survive (budget C), *then* the survivors are
divided into pages (evicting first avoids any cross-page data movement —
paper §4.2). The output is a ready-to-decode :class:`PagedLayerCache`.

This is the offline / whole-prompt API (``forward_prefill``). The SERVING
path compresses incrementally instead: chunks append straight into the
shared pool and ``EvictionPolicy.chunk_prefill_evict`` prunes at each
chunk boundary (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import CacheConfig
from repro.core.paged_cache import (
    PagedLayerCache,
    init_layer_cache,
    write_prompt_pages,
)
from repro.core.policies import EvictionPolicy


def compress_and_page(k, v, positions, valid, policy: EvictionPolicy,
                      cfg: CacheConfig, seq_len_hint: int | None = None,
                      cache_dtype=None) -> PagedLayerCache:
    """Build a paged cache from contiguous prompt K/V.

    k, v      : (B, S, KV, hd)  (RoPE already applied to k)
    positions : (B, S) int32 original token positions
    valid     : (B, S) bool    (padding mask for ragged prompts)
    """
    B, S, KV, hd = k.shape
    page = cfg.page_size
    num_pages = policy.slab_pages(cfg, seq_len_hint or S)

    idx, scores = policy.prefill_keep(k, v, positions, valid, cfg)  # (B, keep)
    keep = idx.shape[1]

    # slab-capacity cap: windowed layers size their slab to the attention
    # window, which can be smaller than the policy's keep set (e.g. full
    # cache on a sliding-window layer keeps only the newest window tokens)
    cap = num_pages * page
    if keep > cap:
        sel_scores = jnp.take_along_axis(scores, idx, axis=1)
        _, sub = jax.lax.top_k(sel_scores, cap)
        sub = jnp.sort(sub, axis=-1)
        idx = jnp.take_along_axis(idx, sub, axis=1)
        keep = cap

    take = lambda arr: jnp.take_along_axis(
        arr, idx.reshape(B, keep, *([1] * (arr.ndim - 2))), axis=1)
    k_sel, v_sel = take(k), take(v)
    pos_sel = jnp.take_along_axis(positions, idx, axis=1)
    score_sel = jnp.take_along_axis(scores, idx, axis=1)
    # -inf marks padding/unselectable; +inf is legitimate (e.g. streaming
    # sinks are pinned with +inf importance)
    valid_sel = jnp.take_along_axis(valid, idx, axis=1) & \
        ~jnp.isneginf(score_sel)
    pos_sel = jnp.where(valid_sel, pos_sel, -1)

    # pad the kept set up to a whole number of pages
    pad = (-keep) % page
    if pad:
        k_sel = jnp.pad(k_sel, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_sel = jnp.pad(v_sel, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_sel = jnp.pad(pos_sel, ((0, 0), (0, pad)), constant_values=-1)
        score_sel = jnp.pad(score_sel, ((0, 0), (0, pad)), constant_values=-jnp.inf)

    cache = init_layer_cache(B, num_pages, page, KV, hd,
                             cache_dtype or k.dtype)
    return write_prompt_pages(cache, k_sel, v_sel, pos_sel, score_sel)
