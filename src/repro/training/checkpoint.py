"""Checkpointing: flat-key .npz snapshots of arbitrary pytrees.

No orbax offline — this implements the same contract: atomic save (write to
tmp then rename), step-indexed directories, latest-step discovery, and
exact pytree restore (structure from a saved keypath manifest).
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, name: str = "state") -> str:
    """Atomic save of ``tree`` under <ckpt_dir>/step_<step>/<name>.npz."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=step_dir, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **flat)
    # np.savez appends .npz when the name lacks it; prefer that artifact
    produced = tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp
    final = os.path.join(step_dir, f"{name}.npz")
    os.replace(produced, final)
    if os.path.exists(tmp):
        os.remove(tmp)
    with open(os.path.join(step_dir, f"{name}.keys.json"), "w") as f:
        json.dump(sorted(flat.keys()), f)
    return final


def load_checkpoint(ckpt_dir: str, step: int, like, name: str = "state"):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(step_dir, f"{name}.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None
