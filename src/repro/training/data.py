"""Synthetic data pipeline (the container has no datasets).

Two generators, both deterministic given a seed and shardable by host:

  lm_batches      Zipf-distributed token soup with local n-gram structure —
                  enough signal for loss to drop and smoke tests to pass.
  recall_batches  the *long-context recall* task used to evaluate eviction
                  quality (the LongBench proxy): a key-value list is embedded
                  early in a long distractor context; the query at the end
                  asks for the value of one key. A model with an evicted
                  cache can only answer if the eviction policy preserved the
                  right tokens — exactly the paper's accuracy axis.

Layout mirrors a production pipeline: an index-based sampler (host-side
numpy), per-host sharding by ``host_id``/``num_hosts``, and an iterator of
ready (tokens, targets, mask) batches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-host batch
    seed: int = 0
    # recall task knobs
    num_pairs: int = 8         # key/value pairs in the preamble
    key_space: int = 64        # token ids reserved for keys
    distractor_frac: float = 0.8


def _rng_for(cfg: DataConfig, step: int, host_id: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))


# ---------------------------------------------------------------------------
# generic LM stream
# ---------------------------------------------------------------------------

def lm_batch(cfg: DataConfig, step: int, host_id: int = 0,
             num_codebooks: int = 1) -> dict:
    rng = _rng_for(cfg, step, host_id)
    V, S, B = cfg.vocab_size, cfg.seq_len, cfg.batch_size
    shape = (B, num_codebooks, S + 1) if num_codebooks > 1 else (B, S + 1)
    # zipf-ish marginal + short repeats for learnable structure
    z = rng.zipf(1.3, size=shape)
    toks = (z % V).astype(np.int32)
    rep = rng.integers(0, 2, size=shape).astype(bool)
    shifted = np.roll(toks, 3, axis=-1)
    toks = np.where(rep, shifted, toks)
    if num_codebooks > 1:
        return {"tokens": toks[..., :-1], "targets": toks[..., 1:],
                "mask": np.ones((B, S), np.float32)}
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
            "mask": np.ones((B, S), np.float32)}


def lm_batches(cfg: DataConfig, host_id: int = 0, num_hosts: int = 1,
               num_codebooks: int = 1) -> Iterator[dict]:
    step = host_id
    while True:
        yield lm_batch(cfg, step, host_id, num_codebooks)
        step += num_hosts


# ---------------------------------------------------------------------------
# long-context recall (eviction-quality eval)
# ---------------------------------------------------------------------------

def recall_example(cfg: DataConfig, rng: np.random.Generator):
    """One example: [pairs .. distractors .. QUERY key] -> value.

    Token map: 0 = pad, 1 = SEP, 2 = QUERY; keys in [3, 3+key_space);
    values in [3+key_space, vocab). Returns (prompt (S,), answer token)."""
    V, S = cfg.vocab_size, cfg.seq_len
    kv_lo = 3
    v_lo = 3 + cfg.key_space
    assert V > v_lo + 8, "vocab too small for recall task"
    keys = rng.choice(np.arange(kv_lo, v_lo), size=cfg.num_pairs, replace=False)
    vals = rng.integers(v_lo, V, size=cfg.num_pairs)
    body = []
    for k, v in zip(keys, vals):
        body += [int(k), int(v), 1]
    qi = rng.integers(0, cfg.num_pairs)
    tail = [2, int(keys[qi])]
    n_dis = S - len(body) - len(tail)
    assert n_dis >= 0, "seq too short for recall task"
    dis = rng.integers(v_lo, V, size=n_dis).tolist()
    prompt = np.array(body + dis + tail, np.int32)
    return prompt, int(vals[qi])


def recall_batch(cfg: DataConfig, step: int, host_id: int = 0) -> dict:
    """Batched recall prompts + answers (for prefill+decode eval) and also a
    teacher-forced training view (predict answer at the last position)."""
    rng = _rng_for(cfg, step, host_id)
    B, S = cfg.batch_size, cfg.seq_len
    prompts = np.zeros((B, S), np.int32)
    answers = np.zeros((B,), np.int32)
    for i in range(B):
        prompts[i], answers[i] = recall_example(cfg, rng)
    # training view: target only at the final position (the answer)
    tokens = prompts
    targets = np.zeros((B, S), np.int32)
    targets[:, :-1] = prompts[:, 1:]
    targets[:, -1] = answers
    mask = np.zeros((B, S), np.float32)
    mask[:, -1] = 1.0                      # score only the answer slot
    return {"tokens": tokens, "targets": targets, "mask": mask,
            "answers": answers}


def recall_batches(cfg: DataConfig, host_id: int = 0,
                   num_hosts: int = 1) -> Iterator[dict]:
    step = host_id
    while True:
        yield recall_batch(cfg, step, host_id)
        step += num_hosts
