"""Training substrate: optimizer, step, data, checkpointing."""
from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_update,
    global_norm,
    init_adamw,
    lr_schedule,
)
from repro.training.train_step import (
    cross_entropy,
    loss_fn,
    make_train_step,
    train_step,
)
from repro.training.data import (
    DataConfig,
    lm_batch,
    lm_batches,
    recall_batch,
    recall_batches,
    recall_example,
)
from repro.training.checkpoint import (
    latest_step,
    load_checkpoint,
    save_checkpoint,
)

__all__ = [
    "AdamWConfig", "AdamWState", "adamw_update", "global_norm", "init_adamw",
    "lr_schedule", "cross_entropy", "loss_fn", "make_train_step", "train_step",
    "DataConfig", "lm_batch", "lm_batches", "recall_batch", "recall_batches",
    "recall_example", "latest_step", "load_checkpoint", "save_checkpoint",
]
