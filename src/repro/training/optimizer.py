"""Hand-rolled AdamW + schedules (no optax in this environment).

Optimizer state is a pytree mirroring params (f32 master copies of moments);
``adamw_update`` is pure and shard-transparent under pjit. ZeRO-1 style
optimizer-state sharding along ``data`` is applied at the launcher level by
sharding the state pytree (see repro.sharding.rules.optimizer_sharding).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    mu: Any              # first moment (f32, like params)
    nu: Any              # second moment (f32)


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    grad_clip: float = 1.0


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to lr_min_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_peak * (cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _decay_mask(path: tuple) -> bool:
    """Weight decay on matrices only — not on norms, biases, or gate biases."""
    last = str(path[-1]) if path else ""
    no_decay = ("norm", "bias", "scale", "b_gates", "b_igate", "b_fgate",
                "bq", "bk", "bv", "dt_bias", "A_log", "D", "conv_b")
    return not any(t in last for t in no_decay)


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 moment_shardings=None):
    """Returns (new_params, new_state, metrics).

    ``moment_shardings``: optional pytree of NamedSharding matching the
    moments (ZeRO-1). When given, the f32 gradient/update math is pinned to
    the moment sharding — the grads are reduce-scattered over the data
    axis, all optimizer arithmetic runs on 1/N-sized shards, and only the
    final (cast-back) update is all-gathered into the parameter sharding.
    Without this, the f32 temporaries are param-sharded and dominate
    training peak memory on 100B+ models (§Perf jamba iter 4)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat_p[0]]
    treedef = flat_p[1]
    p_leaves = [v for _, v in flat_p[0]]
    g_leaves = jax.tree.leaves(grads)
    mu_leaves = jax.tree.leaves(state.mu)
    nu_leaves = jax.tree.leaves(state.nu)
    sh_leaves = (jax.tree.leaves(moment_shardings)
                 if moment_shardings is not None else [None] * len(p_leaves))

    new_p, new_mu, new_nu = [], [], []
    for path, p, g, mu, nu, sh in zip(paths, p_leaves, g_leaves, mu_leaves,
                                      nu_leaves, sh_leaves):
        gf = g.astype(jnp.float32) * clip
        if sh is not None:
            gf = jax.lax.with_sharding_constraint(gf, sh)
        mu2 = cfg.beta1 * mu + (1 - cfg.beta1) * gf
        nu2 = cfg.beta2 * nu + (1 - cfg.beta2) * jnp.square(gf)
        upd = (mu2 / b1c) / (jnp.sqrt(nu2 / b2c) + cfg.eps)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu2)
        new_nu.append(nu2)

    unflatten = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return unflatten(new_p), AdamWState(step=step, mu=unflatten(new_mu),
                                        nu=unflatten(new_nu)), metrics
