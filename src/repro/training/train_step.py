"""Training step: next-token cross-entropy + MoE load-balance aux loss."""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward_train
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update

Identity = lambda x: x


def cross_entropy(logits, targets, mask):
    """logits: (B, S, [K,] V); targets: (B, S) or (B, K, S); mask: (B, S)."""
    if logits.ndim == 4:                    # audio: (B, S, K, V)
        targets = jnp.moveaxis(targets, 1, 2)   # (B, S, K)
        mask = mask[..., None]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def loss_fn(params, cfg: ModelConfig, batch, *, aux_weight: float = 0.01,
            ac: Callable = Identity, cond=None):
    logits, aux = forward_train(params, cfg, batch["tokens"], cond=cond, ac=ac)
    ce = cross_entropy(logits, batch["targets"], batch["mask"])
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def train_step(params, opt_state: AdamWState, batch, *, cfg: ModelConfig,
               opt_cfg: AdamWConfig, aux_weight: float = 0.01,
               ac: Callable = Identity, cond=None, moment_shardings=None):
    """One optimizer step. Pure; jit/pjit at the call site."""
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, aux_weight=aux_weight, ac=ac, cond=cond)
    params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg,
                                         moment_shardings=moment_shardings)
    metrics = {"loss": loss, **parts, **om}
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    aux_weight: float = 0.01, ac: Callable = Identity,
                    moment_shardings=None):
    """Returns a (params, opt_state, batch) -> (params, opt_state, metrics)
    closure suitable for jax.jit / pjit with shardings. Pass the ZeRO-1
    ``moment_shardings`` (rules.opt_shardings(..., zero1=True).mu) to pin
    optimizer math to the data-sharded moments."""
    return partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                   aux_weight=aux_weight, ac=ac,
                   moment_shardings=moment_shardings)
