"""Mixture-of-Experts MLP (Mixtral/Jamba style): top-k softmax router with
sort-based capacity dispatch (static shapes, drop-on-overflow).

Dispatch is the TPU-friendly sort-based scheme (cf. MaxText): tokens are
ranked within their (example, expert) group via cummax-over-run-starts,
tokens beyond ``capacity`` are dropped (their residual path passes through
untouched), and experts run as one batched einsum over a stacked
(B, E, capacity, D) buffer.

Distribution (§Perf mixtral/jamba iterations — see EXPERIMENTS.md):
  * the whole dispatch -> experts -> combine block runs inside a
    ``jax.shard_map`` over the data axes (model axis left AUTO): under
    plain GSPMD propagation the scatter/gather pair was materialized
    REPLICATED over data in f32 (measured 68.7 GB/device tensors on jamba
    train); manual data sharding makes that impossible by construction.
  * expert weights stay tensor-sharded (d_ff over "model") inside the
    auto region; expert-parallel over a factored mesh axis is a further
    variant.
  * capacity is per-example so ranking never crosses the batch dim.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init


class MoEStats(NamedTuple):
    load: jax.Array          # (E,) fraction of routed assignments per expert
    dropped: jax.Array       # () fraction of assignments dropped by capacity
    aux_loss: jax.Array      # () load-balance auxiliary loss (Switch-style)


def _shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """Version-portable shard_map: new jax exposes ``jax.shard_map`` with
    ``axis_names`` (manual set); 0.4.x has ``jax.experimental.shard_map``
    with the complementary ``auto`` set."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - set(manual_axes)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def init_moe(key, cfg: ModelConfig):
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    kr, kg, ku, kd = jax.random.split(key, 4)
    init_e = lambda k, i, o: jax.vmap(
        lambda kk: dense_init(kk, i, o, dt))(jax.random.split(k, E))
    return {
        "router": dense_init(kr, D, E, jnp.float32),
        "w_gate": init_e(kg, D, F),     # (E, D, F)
        "w_up": init_e(ku, D, F),       # (E, D, F)
        "w_down": init_e(kd, F, D),     # (E, F, D)
    }


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    per_expert = tokens_per_group * cfg.num_experts_per_tok / cfg.num_experts
    cap = int(cfg.moe_capacity_factor * per_expert)
    return max(cap - cap % -8, 8)  # round up to a multiple of 8 (TPU lanes)


def _rank_in_expert(flat_e, E: int):
    """flat_e: (B, A) expert id per assignment -> (B, A) rank of each
    assignment within its expert group (per example).

    Sort-based: argsort by expert id groups assignments; rank-within-run
    via cummax of run starts; scatter ranks back. O(B*A) memory — no
    (tokens x experts) cumsum, no cross-shard dependency.
    """
    B, A = flat_e.shape
    perm = jnp.argsort(flat_e, axis=1, stable=True)          # (B, A)
    sorted_e = jnp.take_along_axis(flat_e, perm, axis=1)
    iota = jnp.broadcast_to(jnp.arange(A, dtype=jnp.int32), (B, A))
    start = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_base = jax.lax.cummax(jnp.where(start, iota, -1), axis=1)
    rank_sorted = iota - run_base                            # (B, A)
    rank = jnp.zeros_like(rank_sorted).at[
        jnp.arange(B)[:, None], perm].set(rank_sorted)
    return rank


def _moe_block(params, x, *, cfg: ModelConfig, cap: int, psum_axis=None):
    """The full dispatch -> experts -> combine on a (local) batch.

    x: (B, S, D) -> (out, load (E,), dropped (), aux ()).
    ``psum_axis``: manual-mesh axis name(s) holding the F shards of the
    expert weights — the partial expert outputs are explicitly
    psum-reduced over it (fully-manual Megatron-style schedule)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    A = S * K

    logits = (x.astype(jnp.float32) @ params["router"])       # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)                  # (B, S, K)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, axis=-1, keepdims=True), 1e-9)

    flat_e = topk_e.reshape(B, A)
    rank = _rank_in_expert(flat_e, E)
    keep = rank < cap                                         # (B, A)
    dst = jnp.where(keep, flat_e * cap + rank, E * cap)       # drop slot

    # ---- dispatch: (B, E*cap + 1, D) scatter --------------------------------
    token_of = jnp.arange(A, dtype=jnp.int32) // K            # (A,)
    src = x[:, token_of, :]                                   # (B, A, D)
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype).at[
        jnp.arange(B)[:, None], dst].set(src)
    buf = buf[:, :-1, :].reshape(B, E, cap, D)

    # ---- batched expert compute (F stays model-sharded: auto axis) ----------
    act = activation(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", buf, params["w_gate"])) * \
        jnp.einsum("becd,edf->becf", buf, params["w_up"])
    # f32 accumulator for the cross-shard partial sum
    eout = jnp.einsum("becf,efd->becd", h, params["w_down"],
                      preferred_element_type=jnp.float32)
    if psum_axis is not None:
        eout = jax.lax.psum(eout, axis_name=psum_axis)

    # ---- combine (vmapped 1-D take keeps gather indices (B, A)) -------------
    eflat = eout.astype(x.dtype).reshape(B, E * cap, D)
    safe = jnp.minimum(dst, E * cap - 1)
    gathered = jax.vmap(lambda e, s: jnp.take(e, s, axis=0))(eflat, safe)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    weighted = gathered * topk_p.reshape(B, A, 1).astype(x.dtype)
    out = jnp.sum(weighted.reshape(B, S, K, D), axis=2).astype(x.dtype)

    load = jnp.mean(jax.nn.one_hot(topk_e, E, dtype=jnp.float32), axis=(0, 1, 2))
    importance_frac = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(load * importance_frac)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, load, dropped, aux


def _moe_block_ep(params, x, *, cfg: ModelConfig, cap: int, ep: int):
    """Expert-parallel block (inside a fully-manual shard_map region).

    x: LOCAL (B_loc, S, D); params LOCAL: w_gate/w_up (E_loc, D, F_loc),
    w_down (E_loc, F_loc, D), router replicated. Tokens reach their expert
    owner via all-to-all over the "expert" axis; d_ff psums over "tp"."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    E_loc = E // ep
    A = S * K

    logits = (x.astype(jnp.float32) @ params["router"])       # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, axis=-1, keepdims=True), 1e-9)

    flat_e = topk_e.reshape(B, A)
    rank = _rank_in_expert(flat_e, E)
    keep = rank < cap
    dst = jnp.where(keep, flat_e * cap + rank, E * cap)

    token_of = jnp.arange(A, dtype=jnp.int32) // K
    src = x[:, token_of, :]
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype).at[
        jnp.arange(B)[:, None], dst].set(src)
    buf = buf[:, :-1, :].reshape(B, E, cap, D)

    # ---- forward all-to-all: deliver tokens to expert owners ----------------
    t = jnp.moveaxis(buf, 1, 0).reshape(ep, E_loc, B, cap, D)
    t = jax.lax.all_to_all(t, "expert", split_axis=0, concat_axis=0)
    h_in = jnp.moveaxis(t, 1, 0).reshape(E_loc, ep * B * cap, D)

    act = activation(cfg.act)
    h = act(jnp.einsum("end,edf->enf", h_in, params["w_gate"])) * \
        jnp.einsum("end,edf->enf", h_in, params["w_up"])
    eo = jnp.einsum("enf,efd->end", h, params["w_down"],
                    preferred_element_type=jnp.float32)
    eo = jax.lax.psum(eo, axis_name="tp").astype(x.dtype)

    # ---- reverse all-to-all --------------------------------------------------
    eo = jnp.moveaxis(eo.reshape(E_loc, ep, B, cap, D), 1, 0)
    eo = jax.lax.all_to_all(eo, "expert", split_axis=0, concat_axis=0)
    eout = jnp.moveaxis(eo.reshape(E, B, cap, D), 1, 0)       # (B, E, cap, D)

    eflat = eout.reshape(B, E * cap, D)
    safe = jnp.minimum(dst, E * cap - 1)
    gathered = jax.vmap(lambda e, s: jnp.take(e, s, axis=0))(eflat, safe)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    weighted = gathered * topk_p.reshape(B, A, 1).astype(x.dtype)
    out = jnp.sum(weighted.reshape(B, S, K, D), axis=2).astype(x.dtype)

    load = jnp.mean(jax.nn.one_hot(topk_e, E, dtype=jnp.float32), axis=(0, 1, 2))
    importance_frac = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(load * importance_frac)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, load, dropped, aux


def moe_forward(params, cfg: ModelConfig, x, capacity: int | None = None,
                ac=None):
    """x: (B, S, D) -> (out (B, S, D), MoEStats). ``ac``: activation
    constraint from rules.activation_constraint — when it carries a mesh
    and the batch divides the data axes, the block runs under shard_map
    (manual over data, auto over model)."""
    B, S, D = x.shape
    cap = capacity or moe_capacity(cfg, S)
    mesh = getattr(ac, "mesh", None)
    bax = getattr(ac, "batch_axes", None)
    block = partial(_moe_block, cfg=cfg, cap=cap)

    F = params["w_gate"].shape[-1]
    E = cfg.num_experts
    ep_ok = (mesh is not None and "expert" in mesh.shape
             and E % mesh.shape["expert"] == 0
             and F % mesh.shape["tp"] == 0)
    if mesh is not None and bax is not None and ep_ok:
        # --- expert parallelism: tokens travel, experts stay -----------------
        # batch sharded over (data..., expert) = finer DP; each shard routes
        # its local tokens, all-to-all over "expert" delivers each expert
        # owner its tokens, expert compute tp-shards d_ff, reverse a2a +
        # local combine. Dense layers around this region are untouched
        # (their weights shard over the combined ("expert","tp") axes).
        manual = (bax if isinstance(bax, tuple) else (bax,)) + ("expert", "tp")
        ep = mesh.shape["expert"]
        bax_e = (bax if isinstance(bax, tuple) else (bax,)) + ("expert",)

        def local(p, xl):
            out, load, dropped, aux = _moe_block_ep(
                p, xl, cfg=cfg, cap=cap, ep=ep)
            dp = manual[:-2] + ("expert",)
            load = jax.lax.pmean(load, axis_name=dp)
            dropped = jax.lax.pmean(dropped, axis_name=dp)
            aux = jax.lax.pmean(aux, axis_name=dp)
            return out, load, dropped, aux

        pspec = {
            "router": P(),
            "w_gate": P("expert", None, "tp"),
            "w_up": P("expert", None, "tp"),
            "w_down": P("expert", "tp", None),
        }
        out, load, dropped, aux = _shard_map(
            local, mesh,
            in_specs=(pspec, P(bax_e, None, None)),
            out_specs=(P(bax_e, None, None), P(), P(), P()),
            manual_axes=manual)(params, x)
        return out, MoEStats(load, dropped, aux)

    model_ok = (mesh is not None and "model" in mesh.shape
                and F % mesh.shape["model"] == 0)
    if mesh is not None and bax is not None and model_ok:
        # fully-manual region: data AND model manual; expert weights arrive
        # F-sharded; the partial-sum reduction is an explicit f32 psum
        manual = (bax if isinstance(bax, tuple) else (bax,)) + ("model",)
        block_m = partial(_moe_block, cfg=cfg, cap=cap, psum_axis="model")

        def local(p, xl):
            out, load, dropped, aux = block_m(p, xl)
            load = jax.lax.pmean(load, axis_name=manual[:-1])
            dropped = jax.lax.pmean(dropped, axis_name=manual[:-1])
            aux = jax.lax.pmean(aux, axis_name=manual[:-1])
            return out, load, dropped, aux

        pspec = {
            "router": P(),
            "w_gate": P(None, None, "model"),
            "w_up": P(None, None, "model"),
            "w_down": P(None, "model", None),
        }
        out, load, dropped, aux = _shard_map(
            local, mesh,
            in_specs=(pspec, P(bax, None, None)),
            out_specs=(P(bax, None, None), P(), P(), P()),
            manual_axes=manual)(params, x)
        return out, MoEStats(load, dropped, aux)

    out, load, dropped, aux = block(params, x)
    return out, MoEStats(load, dropped, aux)


def moe_forward_decode(params, cfg: ModelConfig, x, tp_axis=None):
    """Single-token MoE (B, D): dense all-expert combine — for decode
    batches every expert's weights are read anyway (memory-bound), and the
    gather/scatter latency is avoided.

    Under tensor parallelism every expert's d_ff is sharded over ``tp_axis``
    (w_gate/w_up on F, w_down's F contraction); the router runs on the
    replicated input in f32 so routing/gates are identical on every shard,
    and the partial expert outputs are psum'd before the gate combine."""
    B, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)
    topk_p = topk_p / jnp.maximum(jnp.sum(topk_p, axis=-1, keepdims=True), 1e-9)
    gate = jnp.zeros((B, E), jnp.float32)
    gate = gate.at[jnp.arange(B)[:, None], topk_e].set(topk_p)   # (B, E)

    act = activation(cfg.act)
    h = act(jnp.einsum("bd,edf->ebf", x, params["w_gate"])) * \
        jnp.einsum("bd,edf->ebf", x, params["w_up"])
    eout = jnp.einsum("ebf,efd->ebd", h, params["w_down"])       # (E, B, D)
    if tp_axis is not None:
        eout = jax.lax.psum(eout.astype(jnp.float32), tp_axis)
    out = jnp.einsum("ebd,be->bd", eout.astype(jnp.float32), gate)
    return out.astype(x.dtype)
