"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar
memory with recurrent gate feedback).

mLSTM training/prefill uses the **chunkwise-parallel** form (linear-attention
style): quadratic within a chunk, matrix-state handoff between chunks — the
TPU-native adaptation of the paper's fused CUDA recurrence (DESIGN.md §2).
Decode is an exact O(1) recurrent step (including the depthwise-conv window
carried in the state). Both share the same log-space stabilization, so
chunkwise == step-scan up to float error (tested).

sLSTM has true hidden-state feedback through the gates, so it is inherently
sequential: `lax.scan` over tokens with block-diagonal per-head recurrent
matrices.

No KV cache exists in either block — PagedEviction is inapplicable to this
family (DESIGN.md §Arch-applicability); the states below ARE the decode
cache (constant-size: the reason long_500k is natural for this arch).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init

_CONV = 4  # depthwise causal conv kernel width on the q/k branch


class MLSTMState(NamedTuple):
    C: jax.Array     # (B, H, hd, hd) f32 stabilized matrix memory
    n: jax.Array     # (B, H, hd) f32 stabilized normalizer
    m: jax.Array     # (B, H) f32 running log-stabilizer
    conv: jax.Array  # (B, _CONV-1, di) trailing conv inputs


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, D) f32 cell
    n: jax.Array   # (B, D) f32 normalizer
    h: jax.Array   # (B, D) f32 hidden (feeds back into gates)
    m: jax.Array   # (B, D) f32 stabilizer


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    D = cfg.d_model
    di = int(cfg.xlstm_proj_factor * D)
    H = cfg.num_heads
    assert di % H == 0
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], D, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (_CONV, di), jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "wq": dense_init(ks[2], di, di, dt),
        "wk": dense_init(ks[3], di, di, dt),
        "wv": dense_init(ks[4], di, di, dt),
        "w_igate": dense_init(ks[5], di, H, jnp.float32, scale=0.01),
        "b_igate": jnp.full((H,), -3.0, jnp.float32),
        "w_fgate": dense_init(ks[6], di, H, jnp.float32, scale=0.01),
        "b_fgate": jnp.full((H,), 3.0, jnp.float32),
        "out_norm": jnp.ones((di,), dt),
        "down_proj": dense_init(jax.random.fold_in(key, 99), di, D, dt),
    }


def _mlstm_up(params, x):
    """x: (B, S, D) -> u, z: (B, S, di)."""
    return jnp.split(x @ params["up_proj"], 2, axis=-1)


def _conv_seq(params, u, conv_state=None):
    """Depthwise causal conv over the sequence. u: (B, S, di).
    conv_state: optional (B, _CONV-1, di) trailing inputs from the past."""
    B, S, di = u.shape
    if conv_state is None:
        up = jnp.pad(u, ((0, 0), (_CONV - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    xc = sum(up[:, i:i + S] * params["conv_w"][i] for i in range(_CONV))
    return jax.nn.silu(xc + params["conv_b"])


def _qkv_gates_from(params, cfg: ModelConfig, u, xc):
    """u, xc: (B, S, di) -> q,k,v (B,S,H,hd) f32, log-gates i,f (B,S,H)."""
    B, S, di = u.shape
    H = cfg.num_heads
    hd = di // H
    q = (xc @ params["wq"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = ((xc @ params["wk"]) / math.sqrt(hd)).reshape(B, S, H, hd).astype(jnp.float32)
    v = (u @ params["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    xcf = xc.astype(jnp.float32)
    ig = xcf @ params["w_igate"] + params["b_igate"]
    fg = jax.nn.log_sigmoid(xcf @ params["w_fgate"] + params["b_fgate"])
    return q, k, v, ig, fg


def _head_norm(h, scale, eps=1e-6):
    """RMS norm per head over hd, then flatten heads. h: (B,S,H,hd) f32."""
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    out = h * lax.rsqrt(ms + eps)
    B, S, H, hd = h.shape
    return out.reshape(B, S, H * hd) * scale.astype(jnp.float32)


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    hd = di // H
    return MLSTMState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H), -jnp.inf, jnp.float32),
        conv=jnp.zeros((batch, _CONV - 1, di), dtype),
    )


def mlstm_chunkwise(params, cfg: ModelConfig, x, state: MLSTMState | None = None,
                    chunk: int = 256, return_state: bool = False):
    """Chunkwise-parallel mLSTM. x: (B, S, D) -> (B, S, D) [, final state]."""
    B, S, D = x.shape
    di = int(cfg.xlstm_proj_factor * D)
    H = cfg.num_heads
    hd = di // H
    W = min(chunk, S)
    assert S % W == 0, (S, W)
    NC = S // W
    u, z = _mlstm_up(params, x)
    xc = _conv_seq(params, u, None if state is None else state.conv)
    q, k, v, ig, fg = _qkv_gates_from(params, cfg, u, xc)

    if state is None:
        state = mlstm_init_state(cfg, B, x.dtype)

    cq = q.reshape(B, NC, W, H, hd)
    ck = k.reshape(B, NC, W, H, hd)
    cv = v.reshape(B, NC, W, H, hd)
    cig = ig.reshape(B, NC, W, H)
    cfgate = fg.reshape(B, NC, W, H)
    tri = jnp.tril(jnp.ones((W, W), bool))

    def chunk_step(carry, inp):
        C, n, m = carry                                    # (B,H,hd,hd),(B,H,hd),(B,H)
        qc, kc, vc, igc, fgc = inp                         # (B,W,H,hd) / (B,W,H)
        b = jnp.cumsum(fgc, axis=1)                        # cumulative log decay
        b_tot = b[:, -1]                                   # (B,H)
        # intra-chunk log weights D[t,s] = b_t - b_s + i_s for s <= t
        Dts = b[:, :, None, :] - b[:, None, :, :] + igc[:, None, :, :]
        Dts = jnp.where(tri[None, :, :, None], Dts, -jnp.inf)
        m_intra = jnp.max(Dts, axis=2)                     # (B,W,H)
        m_state = m[:, None, :] + b                        # (B,W,H)
        m_t = jnp.maximum(m_state, m_intra)
        m_t = jnp.where(jnp.isneginf(m_t), 0.0, m_t)       # all-empty guard
        w_state = jnp.exp(m_state - m_t)                   # (B,W,H)
        h_inter = jnp.einsum("bwhd,bhde->bwhe", qc, C) * w_state[..., None]
        n_inter = jnp.einsum("bwhd,bhd->bwh", qc, n) * w_state
        P = jnp.exp(Dts - m_t[:, :, None, :])              # (B,t,s,H)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)
        h_intra = jnp.einsum("btsh,btsh,bshe->bthe", P, qk, vc)
        n_intra = jnp.einsum("btsh,btsh->bth", P, qk)
        num = h_inter + h_intra
        den = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))
        h_out = num / den[..., None]
        # ---- state handoff ---------------------------------------------------
        decay_s = igc + (b_tot[:, None, :] - b)            # (B,W,H)
        m_new = jnp.maximum(m + b_tot, jnp.max(decay_s, axis=1))
        w_old = jnp.exp(m + b_tot - m_new)
        w_src = jnp.exp(decay_s - m_new[:, None, :])
        C_new = w_old[..., None, None] * C + \
            jnp.einsum("bwh,bwhd,bwhe->bhde", w_src, kc, vc)
        n_new = w_old[..., None] * n + jnp.einsum("bwh,bwhd->bhd", w_src, kc)
        return (C_new, n_new, m_new), h_out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (cq, ck, cv, cig, cfgate))
    (C, n, m), hs = lax.scan(chunk_step, (state.C, state.n, state.m), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)
    out = _head_norm(h, params["out_norm"]).astype(x.dtype)
    out = (out * jax.nn.silu(z)) @ params["down_proj"]
    if return_state:
        new_conv = jnp.concatenate(
            [state.conv.astype(u.dtype), u], axis=1)[:, -(_CONV - 1):, :]
        return out, MLSTMState(C, n, m, new_conv)
    return out


def mlstm_decode_step(params, cfg: ModelConfig, x, state: MLSTMState):
    """x: (B, D) -> (out (B, D), new state). Exact recurrent step."""
    B, D = x.shape
    u, z = _mlstm_up(params, x[:, None, :])                # (B,1,di)
    xc = _conv_seq(params, u, state.conv)                  # conv window exact
    q, k, v, ig, fg = _qkv_gates_from(params, cfg, u, xc)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                    # (B,H,hd) f32
    ig, fg = ig[:, 0], fg[:, 0]                            # (B,H)
    m_new = jnp.maximum(fg + state.m, ig)
    fprime = jnp.exp(fg + state.m - m_new)
    iprime = jnp.exp(ig - m_new)
    C = fprime[..., None, None] * state.C + \
        iprime[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n = fprime[..., None] * state.n + iprime[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = num / den[..., None]                               # (B,H,hd)
    hn = _head_norm(h[:, None], params["out_norm"])[:, 0].astype(x.dtype)
    out = (hn * jax.nn.silu(z[:, 0])) @ params["down_proj"]
    new_conv = jnp.concatenate(
        [state.conv.astype(u.dtype), u], axis=1)[:, -(_CONV - 1):, :]
    return out, MLSTMState(C, n, m_new, new_conv)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    di = int(cfg.xlstm_proj_factor * D)
    ks = jax.random.split(key, 7)
    r_init = lambda kk: (jax.random.normal(kk, (H, hd, hd), jnp.float32)
                         / math.sqrt(hd))
    return {
        "w_gates": dense_init(ks[0], D, 4 * D, dt),          # z,i,f,o stacked
        "b_gates": jnp.concatenate([
            jnp.zeros((2 * D,), jnp.float32),
            jnp.full((D,), 3.0, jnp.float32),                # forget bias
            jnp.zeros((D,), jnp.float32)]),
        "r_z": r_init(ks[1]), "r_i": r_init(ks[2]),
        "r_f": r_init(ks[3]), "r_o": r_init(ks[4]),
        "out_norm": jnp.ones((D,), dt),
        "up_proj": dense_init(ks[5], D, 2 * di, dt),
        "down_proj": dense_init(ks[6], di, D, dt),
    }


def _slstm_cell(params, cfg: ModelConfig, wx_t, state: SLSTMState):
    """One sLSTM step. wx_t: (B, 4D) precomputed input contribution."""
    D, H = cfg.d_model, cfg.num_heads
    hd = D // H
    B = wx_t.shape[0]
    hprev = state.h.reshape(B, H, hd)
    rec = lambda R: jnp.einsum("bhd,hde->bhe", hprev, R).reshape(B, D)
    z_in, i_in, f_in, o_in = jnp.split(
        wx_t.astype(jnp.float32) + params["b_gates"], 4, axis=-1)
    z = jnp.tanh(z_in + rec(params["r_z"]))
    ig = i_in + rec(params["r_i"])                            # log-space
    fg = jax.nn.log_sigmoid(f_in + rec(params["r_f"]))
    o = jax.nn.sigmoid(o_in + rec(params["r_o"]))
    m_new = jnp.maximum(fg + state.m, ig)
    iprime = jnp.exp(ig - m_new)
    fprime = jnp.exp(fg + state.m - m_new)
    c = fprime * state.c + iprime * z
    n = fprime * state.n + iprime
    h = o * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    D = cfg.d_model
    zero = jnp.zeros((batch, D), jnp.float32)
    return SLSTMState(c=zero, n=zero, h=zero,
                      m=jnp.full((batch, D), -jnp.inf, jnp.float32))


def _slstm_out(params, cfg: ModelConfig, h_seq, x_dtype):
    """Head-group norm + gated up/down FFN. h_seq: (B, S, D) f32."""
    B, S, D = h_seq.shape
    H = cfg.num_heads
    hf = h_seq.reshape(B, S, H, D // H)
    ms = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    hn = (hf * lax.rsqrt(ms + 1e-6)).reshape(B, S, D)
    hn = (hn * params["out_norm"].astype(jnp.float32)).astype(x_dtype)
    u, g = jnp.split(hn @ params["up_proj"], 2, axis=-1)
    return (activation(cfg.act)(g) * u) @ params["down_proj"]


def slstm_forward(params, cfg: ModelConfig, x, state: SLSTMState | None = None,
                  return_state: bool = False):
    """Sequential sLSTM over a sequence. x: (B, S, D)."""
    B, S, D = x.shape
    wx = x @ params["w_gates"]                               # (B, S, 4D)
    if state is None:
        state = slstm_init_state(cfg, B)

    def step(st, wx_t):
        st2 = _slstm_cell(params, cfg, wx_t, st)
        return st2, st2.h

    final, hs = lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h_seq = jnp.moveaxis(hs, 0, 1)                           # (B, S, D) f32
    out = _slstm_out(params, cfg, h_seq, x.dtype)
    if return_state:
        return out, final
    return out


def slstm_decode_step(params, cfg: ModelConfig, x, state: SLSTMState):
    """x: (B, D) -> (out, new state)."""
    wx = x @ params["w_gates"]
    st = _slstm_cell(params, cfg, wx, state)
    out = _slstm_out(params, cfg, st.h[:, None, :], x.dtype)[:, 0]
    return out, st
