"""Selective SSM (Mamba) mixer — Jamba's recurrent layer.

Training/prefill: `lax.scan` over the sequence carrying the (B, d_inner, N)
SSM state (the chunked SSD formulation is a hillclimb variant; the scan
form is the memory-safe baseline and exact).
Decode: O(1) per-step state update — the reason long_500k is natural for
hybrid archs (no KV cache to evict; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


class MambaState(NamedTuple):
    conv: jax.Array   # (B, d_conv - 1, d_inner) — trailing inputs window
    ssm: jax.Array    # (B, d_inner, d_state) f32


def init_mamba(key, cfg: ModelConfig):
    dt_ = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    D, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dr, dc = cfg.resolved_dt_rank, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], D, 2 * di, dt_),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32) * 0.2).astype(dt_),
        "conv_b": jnp.zeros((di,), dt_),
        "x_proj": dense_init(ks[2], di, dr + 2 * ds, dt_),
        "dt_proj": dense_init(ks[3], dr, di, dt_),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                             * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)),
                     1e-4, None))),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, D, dt_),
    }


def _ssm_inputs(params, cfg: ModelConfig, xc):
    """xc: (..., di) post-conv activations -> dt (..., di), Bt, Ct (..., ds)."""
    dr, ds = cfg.resolved_dt_rank, cfg.mamba_d_state
    proj = xc @ params["x_proj"]
    dt_in, Bt, Ct = jnp.split(proj.astype(jnp.float32), [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])
    return dt, Bt, Ct


def mamba_forward(params, cfg: ModelConfig, x, ac=None):
    """Full-sequence selective scan. x: (B, S, D) -> (B, S, D).

    ``ac``: activation-sharding hook (rules.activation_constraint). The
    (B, S, di) intermediates and the time-major scan inputs are pinned
    explicitly — GSPMD drops their sharding through the moveaxis/scan
    boundary otherwise (268 GB/device replicated f32 on jamba train).
    """
    from repro.sharding.rules import pin_inner, pin_time
    pi, pt = pin_inner(ac), pin_time(ac)
    B, S, D = x.shape
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                       # (B, S, di)
    xin, z = pi(xin), pi(z)

    # depthwise causal conv1d
    xp = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + S] * params["conv_w"][i] for i in range(dc))
    xc = pi(jax.nn.silu(xc + params["conv_b"]))

    dt, Bt, Ct = _ssm_inputs(params, cfg, xc)                # f32
    dt = pi(dt)
    A = -jnp.exp(params["A_log"])                            # (di, ds)
    # avoid materializing (B,S,di,ds): scan over S instead
    xcf = pi(xc.astype(jnp.float32))

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp                            # (B,di),(B,ds),(B,ds),(B,di)
        dA_t = jnp.exp(dt_t[..., None] * A)                  # (B, di, ds)
        dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]      # (B, di, ds)
        h = dA_t * h + dBx
        y = jnp.einsum("bds,bs->bd", h, C_t)                 # (B, di)
        return h, y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    xs = (pt(jnp.moveaxis(dt, 1, 0)), jnp.moveaxis(Bt, 1, 0),
          jnp.moveaxis(Ct, 1, 0), pt(jnp.moveaxis(xcf, 1, 0)))

    # nested chunked scan: the outer scan saves only chunk-boundary states
    # for the backward pass; each (rematted) inner chunk recomputes its
    # per-step (B, di, ds) discretization tensors instead of storing S of
    # them (§Perf jamba iter 3 — the SSD-style memory profile without the
    # blocked matmul formulation)
    W = 256 if S % 256 == 0 else (64 if S % 64 == 0 else 1)
    if W > 1:
        xs_c = jax.tree.map(lambda a: a.reshape(S // W, W, *a.shape[1:]), xs)

        def chunk(h, ch):
            return lax.scan(step, h, ch)

        _, ys = lax.scan(jax.checkpoint(chunk, prevent_cse=False), h0, xs_c)
        ys = ys.reshape(S, B, di)
    else:
        _, ys = lax.scan(step, h0, xs)                       # (S, B, di)
    y = pi(jnp.moveaxis(pt(ys), 0, 1)) + xcf * params["D"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return out


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return MambaState(
        conv=jnp.zeros((batch, dc - 1, di), dtype),
        ssm=jnp.zeros((batch, di, ds), jnp.float32),
    )


def mamba_prefill(params, cfg: ModelConfig, x):
    """Like mamba_forward but also returns the final recurrent state so
    decode can continue. x: (B, S, D) -> (out, MambaState)."""
    B, S, D = x.shape
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xp = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + S] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + params["conv_b"])
    dt, Bt, Ct = _ssm_inputs(params, cfg, xc)
    A = -jnp.exp(params["A_log"])
    xcf = xc.astype(jnp.float32)

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp
        dA_t = jnp.exp(dt_t[..., None] * A)
        h = dA_t * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        return h, jnp.einsum("bds,bs->bd", h, C_t)

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bt, 1, 0),
          jnp.moveaxis(Ct, 1, 0), jnp.moveaxis(xcf, 1, 0))
    h_final, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xcf * params["D"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    state = MambaState(conv=xin[:, S - (dc - 1):, :], ssm=h_final)
    return out, state


def mamba_decode_step(params, cfg: ModelConfig, x, state: MambaState):
    """Single-token update. x: (B, D) -> (out (B, D), new state)."""
    B, D = x.shape
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                       # (B, di)
    window = jnp.concatenate([state.conv, xin[:, None, :]], axis=1)  # (B, dc, di)
    xc = jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32))
    xc = jax.nn.silu(xc + params["conv_b"].astype(jnp.float32)).astype(x.dtype)
    dt, Bt, Ct = _ssm_inputs(params, cfg, xc)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)                          # (B, di, ds)
    h = dA * state.ssm + (dt * xc.astype(jnp.float32))[..., None] * Bt[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, Ct) + xc.astype(jnp.float32) * params["D"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return out, MambaState(conv=window[:, 1:, :], ssm=h)
