"""Generic decoder stack assembled from a ModelConfig's layer pattern.

Three execution modes:
  forward_train   contiguous causal forward, logits over the whole sequence
  forward_prefill contiguous forward that *builds the paged KV caches*
                  (paper Alg.2 compression applied per layer before paging)
  decode_step     one token per request against paged caches / recurrent
                  states (paper Alg.3 eviction runs inside each attn layer)

Deep stacks are lowered as ``lax.scan`` over repetitions of the layer
pattern with stacked parameters: HLO size is O(pattern period), not
O(num_layers) (gemma3: 6, jamba: 8, dense: 1). The remainder
(num_layers mod period) is unrolled ("tail").
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import CacheConfig, LayerSpec, ModelConfig
from repro.core.paged_cache import PagedLayerCache, write_token
from repro.core.policies import EvictionPolicy
from repro.core.prefill import compress_and_page
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import StaticKVCache
from repro.models.common import apply_norm, dtype_of, embed_init, init_norm
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward, moe_forward_decode

Identity = lambda x: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg.dtype)
    p: dict[str, Any] = {"norm1": init_norm(cfg.norm, cfg.d_model, dt)}
    if spec.mixer == "attn":
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
        if cfg.cross_attention:
            p["xattn"] = attn_mod.init_attention(ks[1], cfg, cross=True)
            p["norm_x"] = init_norm(cfg.norm, cfg.d_model, dt)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp == "dense":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dt)
        p["mlp"] = init_mlp(ks[2], cfg)
    elif spec.mlp == "moe":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dt)
        p["moe"] = init_moe(ks[2], cfg)
    return p


def init_model(key, cfg: ModelConfig):
    cfg.validate()
    dt = dtype_of(cfg.dtype)
    pat = cfg.layer_pattern()
    P, R, rem = cfg.pattern_period, cfg.full_pattern_reps, cfg.remainder_layers
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    if cfg.num_codebooks > 1:
        params["embed"] = jax.vmap(
            lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt)
        )(jax.random.split(keys[0], cfg.num_codebooks))
    else:
        params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)

    # pattern slots, each stacked over R repetitions
    def slot_init(slot_key, spec):
        return jax.vmap(lambda k: init_layer(k, cfg, spec))(
            jax.random.split(slot_key, R))

    slot_keys = jax.random.split(keys[1], P)
    params["pattern"] = [slot_init(slot_keys[i], pat[i]) for i in range(P)] \
        if R > 0 else []
    tail_keys = jax.random.split(keys[2], max(rem, 1))
    params["tail"] = [init_layer(tail_keys[i], cfg, pat[i]) for i in range(rem)]
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["lm_head"] = jax.vmap(
                lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt)
            )(jax.random.split(keys[3], cfg.num_codebooks))
        else:
            params["lm_head"] = embed_init(keys[3], cfg.vocab_size, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# embeddings / logits (modality-aware; stubs documented in multimodal.py)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    """text/vlm: tokens (B, S) -> (B, S, D). audio: (B, K, S) -> sum of
    per-codebook embeddings (MusicGen-style)."""
    if cfg.num_codebooks > 1:
        # tokens: (B, K, S); embed: (K, V, D) — per-codebook lookup, summed
        per_cb = jax.vmap(lambda emb, tok: jnp.take(emb, tok, axis=0),
                          in_axes=(0, 1))(params["embed"], tokens)  # (K, B, S, D)
        return jnp.sum(per_cb, axis=0)
    return jnp.take(params["embed"], tokens, axis=0)


def lm_logits(params, cfg: ModelConfig, x):
    """x: (B, [S,] D) -> logits (B, [S,] vocab) or (B, [S,] K, vocab)."""
    x = apply_norm(params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.num_codebooks > 1:
        out = jnp.einsum("...d,kvd->...kv", x, head)
    else:
        out = jnp.einsum("...d,vd->...v", x, head)
    from repro.models.common import soft_cap
    return soft_cap(out.astype(jnp.float32), cfg.logit_soft_cap)


# ---------------------------------------------------------------------------
# per-layer forward (contiguous)
# ---------------------------------------------------------------------------

def _spec_window(cfg: ModelConfig, spec: LayerSpec) -> int:
    if spec.attn_kind == "swa":
        return cfg.sliding_window
    if spec.attn_kind == "local":
        return cfg.local_window
    return 0


def layer_forward(lp, cfg: ModelConfig, spec: LayerSpec, x, positions,
                  cond=None, ac: Callable = Identity, return_kv: bool = False,
                  return_state: bool = False, use_pallas: bool = False):
    """One decoder layer over a contiguous sequence.

    Returns (x, aux_loss, extras) where extras carries KV (attn) or the
    final recurrent state (mamba/xlstm) when requested.
    """
    x = ac(x)
    h = apply_norm(lp["norm1"], x)
    extras = None
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer == "attn":
        a, kv = attn_mod.attention_forward(
            lp["attn"], cfg, spec, h, positions, return_kv=return_kv,
            use_pallas=use_pallas)
        x = x + a
        if cond is not None and "xattn" in lp:
            hx = apply_norm(lp["norm_x"], x)
            xc = attn_mod.make_cross_cache(lp["xattn"], cfg, cond)
            x = x + attn_mod.cross_attention_forward(lp["xattn"], cfg, hx, xc)
        extras = kv
    elif spec.mixer == "mamba":
        if return_state:
            m, st = mamba_mod.mamba_prefill(lp["mamba"], cfg, h)
            extras = st
        else:
            m = mamba_mod.mamba_forward(lp["mamba"], cfg, h, ac=ac)
        x = x + m
    elif spec.mixer == "mlstm":
        if return_state:
            m, st = xlstm_mod.mlstm_chunkwise(lp["mlstm"], cfg, h,
                                              return_state=True)
            extras = st
        else:
            m = xlstm_mod.mlstm_chunkwise(lp["mlstm"], cfg, h)
        x = x + m
    elif spec.mixer == "slstm":
        if return_state:
            m, st = xlstm_mod.slstm_forward(lp["slstm"], cfg, h,
                                            return_state=True)
            extras = st
        else:
            m = xlstm_mod.slstm_forward(lp["slstm"], cfg, h)
        x = x + m
    if spec.mlp == "dense":
        h2 = apply_norm(lp["norm2"], x)
        x = x + mlp_forward(lp["mlp"], cfg, h2)
    elif spec.mlp == "moe":
        h2 = apply_norm(lp["norm2"], x)
        mo, stats = moe_forward(lp["moe"], cfg, h2, ac=ac)
        x = x + mo
        aux = stats.aux_loss
    return x, aux, extras


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, tokens, cond=None,
                  ac: Callable = Identity, remat: bool = True,
                  use_pallas: bool = False):
    """tokens: (B, S) [or (B, K, S) audio] -> (logits, aux_loss)."""
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pat = cfg.layer_pattern()
    P = cfg.pattern_period

    def rep_body(carry, slot_params):
        x, aux = carry
        for p in range(P):
            x, a, _ = layer_forward(slot_params[p], cfg, pat[p], x, positions,
                                    cond=cond, ac=ac, use_pallas=use_pallas)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(rep_body, prevent_cse=False) if remat else rep_body
    carry = (x, jnp.zeros((), jnp.float32))
    if params["pattern"]:
        carry, _ = lax.scan(body, carry, tuple(params["pattern"]))
    x, aux = carry
    for i, lp in enumerate(params["tail"]):
        x, a, _ = layer_forward(lp, cfg, pat[i], x, positions, cond=cond,
                                ac=ac, use_pallas=use_pallas)
        aux = aux + a
    return lm_logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class LayerCaches(NamedTuple):
    """Per-layer decode state for one pattern slot (or tail layer). Exactly
    one of the fields is populated, matching the slot's mixer kind; ``xattn``
    rides along with ``kv`` for cross-attention archs."""
    kv: Any = None        # PagedLayerCache (attn)
    xattn: Any = None     # StaticKVCache (attn + cross_attention)
    mamba: Any = None     # MambaState
    mlstm: Any = None     # MLSTMState
    slstm: Any = None     # SLSTMState


class ModelCache(NamedTuple):
    pattern: Any          # list over P slots; leaves stacked (R, ...)
    tail: Any             # list over remainder layers (unstacked)
    cur_pos: jax.Array    # (B,) int32 — next token position per request


def _layer_cache_shapes(cfg: ModelConfig, spec: LayerSpec, batch: int,
                        seq_len: int, policy: EvictionPolicy,
                        ccfg: CacheConfig):
    """Slab sizing for one layer (window-aware; see DESIGN.md §3)."""
    window = _spec_window(cfg, spec)
    hint = seq_len if not window else min(seq_len, window + ccfg.page_size)
    return policy.slab_pages(ccfg, hint)


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int,
                       policy: EvictionPolicy, ccfg: CacheConfig,
                       cond=None, dtype=None):
    """Empty caches for decode-from-scratch (or dry-run ShapeDtype specs)."""
    from repro.core.paged_cache import init_layer_cache
    dt = dtype or dtype_of(ccfg.dtype)
    pat = cfg.layer_pattern()
    P, R, rem = cfg.pattern_period, cfg.full_pattern_reps, cfg.remainder_layers
    hd = cfg.resolved_head_dim

    def one(spec) -> LayerCaches:
        if spec.mixer == "attn":
            pages = _layer_cache_shapes(cfg, spec, batch, seq_len, policy, ccfg)
            kv = init_layer_cache(batch, pages, ccfg.page_size,
                                  cfg.num_kv_heads, hd, dt)
            xa = None
            if cfg.cross_attention:
                xa = StaticKVCache(
                    k=jnp.zeros((batch, cfg.cond_len, cfg.num_kv_heads, hd), dt),
                    v=jnp.zeros((batch, cfg.cond_len, cfg.num_kv_heads, hd), dt))
            return LayerCaches(kv=kv, xattn=xa)
        if spec.mixer == "mamba":
            return LayerCaches(mamba=mamba_mod.mamba_init_state(cfg, batch, dt))
        if spec.mixer == "mlstm":
            return LayerCaches(mlstm=xlstm_mod.mlstm_init_state(cfg, batch, dt))
        return LayerCaches(slstm=xlstm_mod.slstm_init_state(cfg, batch))

    stack = lambda c: jax.tree.map(lambda a: jnp.broadcast_to(a, (R,) + a.shape), c)
    pattern = [stack(one(pat[p])) for p in range(P)] if R > 0 else []
    tail = [one(pat[i]) for i in range(rem)]
    return ModelCache(pattern=pattern, tail=tail,
                      cur_pos=jnp.zeros((batch,), jnp.int32))


# ---------------------------------------------------------------------------
# request insertion (continuous batching)
# ---------------------------------------------------------------------------

def _splice_layer_caches(batch_lc: LayerCaches, single_lc: LayerCaches,
                         slot: int, stacked: bool) -> LayerCaches:
    """Splice one prefilled (batch-1) layer cache into the batch cache.

    Paged KV caches splice through the page pool (free old row, allocate
    fresh pages, copy, rewrite the block-table row — paged_cache.
    insert_request); recurrent states / static cross-KV are plain
    batch-row writes. ``stacked``: leaves carry a leading (R,) repetition
    dim (pattern slots) — the pool splice is vmapped over it."""
    from repro.core.paged_cache import insert_request

    kv = batch_lc.kv
    if kv is not None:
        ins = lambda b_kv, s_kv: insert_request(b_kv, s_kv, slot)
        kv = jax.vmap(ins)(kv, single_lc.kv) if stacked \
            else ins(kv, single_lc.kv)

    def splice(b, s):
        if stacked:
            return b.at[:, slot].set(s[:, 0].astype(b.dtype))
        return b.at[slot].set(s[0].astype(b.dtype))

    rest = {}
    for f in ("xattn", "mamba", "mlstm", "slstm"):
        bf, sf = getattr(batch_lc, f), getattr(single_lc, f)
        rest[f] = jax.tree.map(splice, bf, sf) if bf is not None else None
    return LayerCaches(kv=kv, **rest)


def insert_request_cache(batch_cache: "ModelCache", single_cache: "ModelCache",
                         slot: int) -> "ModelCache":
    """Splice a prefilled single-request ModelCache into batch row ``slot``."""
    pattern = [_splice_layer_caches(bl, sl, slot, stacked=True)
               for bl, sl in zip(batch_cache.pattern, single_cache.pattern)]
    tail = [_splice_layer_caches(bl, sl, slot, stacked=False)
            for bl, sl in zip(batch_cache.tail, single_cache.tail)]
    cur_pos = batch_cache.cur_pos.at[slot].set(single_cache.cur_pos[0])
    return ModelCache(pattern=pattern, tail=tail, cur_pos=cur_pos)


# ---------------------------------------------------------------------------
# prefill forward (build caches)
# ---------------------------------------------------------------------------

def _prefill_layer(lp, cfg, spec, x, positions, valid, cond, policy, ccfg,
                   seq_len_hint, ac: Callable = Identity,
                   use_pallas: bool = False) -> tuple:
    """Layer forward that also produces its decode cache."""
    x, aux, extras = layer_forward(
        lp, cfg, spec, x, positions, cond=cond, ac=ac,
        return_kv=(spec.mixer == "attn"), return_state=(spec.mixer != "attn"),
        use_pallas=use_pallas)
    if spec.mixer == "attn":
        k, v = extras
        window = _spec_window(cfg, spec)
        hint = seq_len_hint if not window else min(
            seq_len_hint, window + ccfg.page_size)
        kv_valid = valid
        if window:
            # windowed layers never attend past the window again: drop
            # out-of-window tokens at paging time (keeps slab small)
            cur = jnp.max(jnp.where(valid, positions, -1), axis=-1, keepdims=True)
            kv_valid = valid & (positions > cur - window)
        cache = compress_and_page(k, v, positions, kv_valid, policy, ccfg,
                                  seq_len_hint=hint,
                                  cache_dtype=dtype_of(ccfg.dtype))
        xa = None
        if cond is not None and "xattn" in lp:
            xa = attn_mod.make_cross_cache(lp["xattn"], cfg, cond)
        return x, aux, LayerCaches(kv=cache, xattn=xa)
    if spec.mixer == "mamba":
        return x, aux, LayerCaches(mamba=extras)
    if spec.mixer == "mlstm":
        return x, aux, LayerCaches(mlstm=extras)
    return x, aux, LayerCaches(slstm=extras)


def forward_prefill(params, cfg: ModelConfig, tokens, policy: EvictionPolicy,
                    ccfg: CacheConfig, cond=None, valid=None,
                    ac: Callable = Identity, total_seq_hint: int | None = None,
                    use_pallas: bool = False):
    """Process the prompt, compress each attn layer's KV per Alg.2, return
    (last-token logits, ModelCache).

    ``total_seq_hint``: expected prompt+generation length — sizes the page
    slabs so decode can continue in-place (defaults to the prompt length)."""
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if valid is None:
        valid = jnp.ones((B, S), bool)
    positions = jnp.where(valid, positions, -1)
    pat = cfg.layer_pattern()
    P = cfg.pattern_period
    hint = total_seq_hint or S

    def rep_body(carry, slot_params):
        x, aux = carry
        caches = []
        for p in range(P):
            x, a, c = _prefill_layer(slot_params[p], cfg, pat[p], x, positions,
                                     valid, cond, policy, ccfg, hint, ac=ac,
                                     use_pallas=use_pallas)
            aux = aux + a
            caches.append(c)
        return (x, aux), tuple(caches)

    carry = (x, jnp.zeros((), jnp.float32))
    if params["pattern"]:
        carry, pattern_caches = lax.scan(rep_body, carry, tuple(params["pattern"]))
        pattern_caches = list(pattern_caches)
    else:
        pattern_caches = []
    x, aux = carry
    tail_caches = []
    for i, lp in enumerate(params["tail"]):
        x, a, c = _prefill_layer(lp, cfg, pat[i], x, positions, valid, cond,
                                 policy, ccfg, hint, ac=ac,
                                 use_pallas=use_pallas)
        aux = aux + a
        tail_caches.append(c)

    # last valid token's hidden state -> next-token logits
    last_idx = jnp.maximum(jnp.sum(valid.astype(jnp.int32), axis=-1) - 1, 0)
    x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(params, cfg, x_last)
    next_pos = jnp.sum(valid.astype(jnp.int32), axis=-1)
    cache = ModelCache(pattern=pattern_caches, tail=tail_caches,
                       cur_pos=next_pos)
    return logits, cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _decode_layer(lp, cfg, spec, x, cache: LayerCaches, cur_pos,
                  policy: EvictionPolicy, ccfg: CacheConfig, active,
                  use_pallas: bool = False):
    """One layer, one token. x: (B, D). Returns (x, LayerCaches)."""
    h = apply_norm(lp["norm1"], x)
    if spec.mixer == "attn":
        q, k, v = attn_mod.decode_project_qkv(lp["attn"], cfg, h, cur_pos)
        kvc: PagedLayerCache = cache.kv
        score = policy.write_score(k, v, cur_pos)
        kvc = write_token(kvc, k, v, cur_pos, score, active=active)
        window = _spec_window(cfg, spec)
        if use_pallas:
            from repro.kernels.ops import paged_attention
            o = paged_attention(q, kvc, cur_pos=cur_pos, window=window)
        else:
            o = attn_mod.paged_attention_ref(q, kvc, cur_pos=cur_pos,
                                             window=window)
        outcome = policy.post_write(kvc, ccfg, active=active)
        kvc = outcome.cache
        B = x.shape[0]
        o = o.reshape(B, -1) @ lp["attn"]["wo"]
        x = x + o
        if cache.xattn is not None:
            hx = apply_norm(lp["norm_x"], x[:, None, :])
            o2 = attn_mod.cross_attention_forward(lp["xattn"], cfg, hx,
                                                  cache.xattn)
            x = x + o2[:, 0]
        cache = cache._replace(kv=kvc)
    elif spec.mixer == "mamba":
        m, st = mamba_mod.mamba_decode_step(lp["mamba"], cfg, h, cache.mamba)
        x = x + m
        cache = cache._replace(mamba=st)
    elif spec.mixer == "mlstm":
        m, st = xlstm_mod.mlstm_decode_step(lp["mlstm"], cfg, h, cache.mlstm)
        x = x + m
        cache = cache._replace(mlstm=st)
    elif spec.mixer == "slstm":
        m, st = xlstm_mod.slstm_decode_step(lp["slstm"], cfg, h, cache.slstm)
        x = x + m
        cache = cache._replace(slstm=st)
    if spec.mlp == "dense":
        h2 = apply_norm(lp["norm2"], x)
        x = x + mlp_forward(lp["mlp"], cfg, h2)
    elif spec.mlp == "moe":
        h2 = apply_norm(lp["norm2"], x)
        x = x + moe_forward_decode(lp["moe"], cfg, h2)
    return x, cache


def decode_step(params, cfg: ModelConfig, tokens, cache: ModelCache,
                policy: EvictionPolicy, ccfg: CacheConfig, active=None,
                use_pallas: bool = False, ac: Callable = Identity):
    """One decode step. tokens: (B,) [or (B, K) audio] -> (logits, cache)."""
    if cfg.num_codebooks > 1:
        # tokens: (B, K); embed: (K, V, D)
        per_cb = jax.vmap(lambda emb, tok: jnp.take(emb, tok, axis=0),
                          in_axes=(0, 1))(params["embed"], tokens)  # (K, B, D)
        x = jnp.sum(per_cb, axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)        # (B, D)
    B = x.shape[0]
    if active is None:
        active = jnp.ones((B,), bool)
    cur_pos = cache.cur_pos
    pat = cfg.layer_pattern()
    P = cfg.pattern_period

    def rep_body(x, xs):
        slot_params, slot_caches = xs
        new_caches = []
        for p in range(P):
            x, c = _decode_layer(slot_params[p], cfg, pat[p], ac(x),
                                 slot_caches[p], cur_pos, policy, ccfg,
                                 active, use_pallas)
            new_caches.append(c)
        return x, tuple(new_caches)

    if params["pattern"]:
        x, pattern_caches = lax.scan(
            rep_body, x, (tuple(params["pattern"]), tuple(cache.pattern)))
        pattern_caches = list(pattern_caches)
    else:
        pattern_caches = []
    tail_caches = []
    for i, lp in enumerate(params["tail"]):
        x, c = _decode_layer(lp, cfg, pat[i], ac(x), cache.tail[i], cur_pos,
                             policy, ccfg, active, use_pallas)
        tail_caches.append(c)
    logits = lm_logits(params, cfg, x)
    new_pos = jnp.where(active, cur_pos + 1, cur_pos)
    return logits, ModelCache(pattern=pattern_caches, tail=tail_caches,
                              cur_pos=new_pos)
