"""Generic decoder stack assembled from a ModelConfig's layer pattern.

Four execution modes:
  forward_train   contiguous causal forward, logits over the whole sequence
  forward_prefill contiguous forward that *builds the paged KV caches*
                  (paper Alg.2 one-shot compression per layer before paging
                  — offline / whole-prompt flows)
  forward_step    UNIFIED mixed-batch step (the serving hot path, DESIGN.md
                  §6): up to T tokens per request — decode rows append 1,
                  prefilling rows append a prompt chunk — written straight
                  into the shared page pool (``append_chunk``), attended
                  write-then-attend through block tables, with Alg.3
                  eviction on decode rows and incremental Alg.2 compression
                  (``chunk_prefill_evict``) at each prefill chunk boundary
  decode_step     one token for every request (the T == 1 specialization,
                  kept as the standalone single-token API)

Deep stacks are lowered as ``lax.scan`` over repetitions of the layer
pattern with stacked parameters: HLO size is O(pattern period), not
O(num_layers) (gemma3: 6, jamba: 8, dense: 1). The remainder
(num_layers mod period) is unrolled ("tail").
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import CacheConfig, LayerSpec, ModelConfig
from repro.core import devstats
from repro.core.paged_cache import (
    PagedLayerCache,
    adopt_prefix,
    append_chunk,
    chunk_rollover,
    release_rows,
    row_intact_prefix_pages,
    write_token,
)
from repro.core.policies import EvictionPolicy
from repro.core.prefill import compress_and_page
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import StaticKVCache
from repro.models.common import apply_norm, dtype_of, embed_init, init_norm
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward, moe_forward_decode

Identity = lambda x: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg.dtype)
    p: dict[str, Any] = {"norm1": init_norm(cfg.norm, cfg.d_model, dt)}
    if spec.mixer == "attn":
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
        if cfg.cross_attention:
            p["xattn"] = attn_mod.init_attention(ks[1], cfg, cross=True)
            p["norm_x"] = init_norm(cfg.norm, cfg.d_model, dt)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp == "dense":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dt)
        p["mlp"] = init_mlp(ks[2], cfg)
    elif spec.mlp == "moe":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dt)
        p["moe"] = init_moe(ks[2], cfg)
    return p


def init_model(key, cfg: ModelConfig):
    cfg.validate()
    dt = dtype_of(cfg.dtype)
    pat = cfg.layer_pattern()
    P, R, rem = cfg.pattern_period, cfg.full_pattern_reps, cfg.remainder_layers
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    if cfg.num_codebooks > 1:
        params["embed"] = jax.vmap(
            lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt)
        )(jax.random.split(keys[0], cfg.num_codebooks))
    else:
        params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)

    # pattern slots, each stacked over R repetitions
    def slot_init(slot_key, spec):
        return jax.vmap(lambda k: init_layer(k, cfg, spec))(
            jax.random.split(slot_key, R))

    slot_keys = jax.random.split(keys[1], P)
    params["pattern"] = [slot_init(slot_keys[i], pat[i]) for i in range(P)] \
        if R > 0 else []
    tail_keys = jax.random.split(keys[2], max(rem, 1))
    params["tail"] = [init_layer(tail_keys[i], cfg, pat[i]) for i in range(rem)]
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["lm_head"] = jax.vmap(
                lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt)
            )(jax.random.split(keys[3], cfg.num_codebooks))
        else:
            params["lm_head"] = embed_init(keys[3], cfg.vocab_size, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# embeddings / logits (modality-aware; stubs documented in multimodal.py)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    """text/vlm: tokens (B, S) -> (B, S, D). audio: (B, K, S) -> sum of
    per-codebook embeddings (MusicGen-style)."""
    if cfg.num_codebooks > 1:
        # tokens: (B, K, S); embed: (K, V, D) — per-codebook lookup, summed
        per_cb = jax.vmap(lambda emb, tok: jnp.take(emb, tok, axis=0),
                          in_axes=(0, 1))(params["embed"], tokens)  # (K, B, S, D)
        return jnp.sum(per_cb, axis=0)
    return jnp.take(params["embed"], tokens, axis=0)


def lm_logits(params, cfg: ModelConfig, x):
    """x: (B, [S,] D) -> logits (B, [S,] vocab) or (B, [S,] K, vocab)."""
    x = apply_norm(params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.num_codebooks > 1:
        out = jnp.einsum("...d,kvd->...kv", x, head)
    else:
        out = jnp.einsum("...d,vd->...v", x, head)
    from repro.models.common import soft_cap
    return soft_cap(out.astype(jnp.float32), cfg.logit_soft_cap)


# ---------------------------------------------------------------------------
# per-layer forward (contiguous)
# ---------------------------------------------------------------------------

def _spec_window(cfg: ModelConfig, spec: LayerSpec) -> int:
    if spec.attn_kind == "swa":
        return cfg.sliding_window
    if spec.attn_kind == "local":
        return cfg.local_window
    return 0


def layer_forward(lp, cfg: ModelConfig, spec: LayerSpec, x, positions,
                  cond=None, ac: Callable = Identity, return_kv: bool = False,
                  return_state: bool = False, use_pallas: bool = False):
    """One decoder layer over a contiguous sequence.

    Returns (x, aux_loss, extras) where extras carries KV (attn) or the
    final recurrent state (mamba/xlstm) when requested.
    """
    x = ac(x)
    h = apply_norm(lp["norm1"], x)
    extras = None
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer == "attn":
        a, kv = attn_mod.attention_forward(
            lp["attn"], cfg, spec, h, positions, return_kv=return_kv,
            use_pallas=use_pallas)
        x = x + a
        if cond is not None and "xattn" in lp:
            hx = apply_norm(lp["norm_x"], x)
            xc = attn_mod.make_cross_cache(lp["xattn"], cfg, cond)
            x = x + attn_mod.cross_attention_forward(lp["xattn"], cfg, hx, xc)
        extras = kv
    elif spec.mixer == "mamba":
        if return_state:
            m, st = mamba_mod.mamba_prefill(lp["mamba"], cfg, h)
            extras = st
        else:
            m = mamba_mod.mamba_forward(lp["mamba"], cfg, h, ac=ac)
        x = x + m
    elif spec.mixer == "mlstm":
        if return_state:
            m, st = xlstm_mod.mlstm_chunkwise(lp["mlstm"], cfg, h,
                                              return_state=True)
            extras = st
        else:
            m = xlstm_mod.mlstm_chunkwise(lp["mlstm"], cfg, h)
        x = x + m
    elif spec.mixer == "slstm":
        if return_state:
            m, st = xlstm_mod.slstm_forward(lp["slstm"], cfg, h,
                                            return_state=True)
            extras = st
        else:
            m = xlstm_mod.slstm_forward(lp["slstm"], cfg, h)
        x = x + m
    if spec.mlp == "dense":
        h2 = apply_norm(lp["norm2"], x)
        x = x + mlp_forward(lp["mlp"], cfg, h2)
    elif spec.mlp == "moe":
        h2 = apply_norm(lp["norm2"], x)
        mo, stats = moe_forward(lp["moe"], cfg, h2, ac=ac)
        x = x + mo
        aux = stats.aux_loss
    return x, aux, extras


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, tokens, cond=None,
                  ac: Callable = Identity, remat: bool = True,
                  use_pallas: bool = False):
    """tokens: (B, S) [or (B, K, S) audio] -> (logits, aux_loss)."""
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pat = cfg.layer_pattern()
    P = cfg.pattern_period

    def rep_body(carry, slot_params):
        x, aux = carry
        for p in range(P):
            x, a, _ = layer_forward(slot_params[p], cfg, pat[p], x, positions,
                                    cond=cond, ac=ac, use_pallas=use_pallas)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(rep_body, prevent_cse=False) if remat else rep_body
    carry = (x, jnp.zeros((), jnp.float32))
    if params["pattern"]:
        carry, _ = lax.scan(body, carry, tuple(params["pattern"]))
    x, aux = carry
    for i, lp in enumerate(params["tail"]):
        x, a, _ = layer_forward(lp, cfg, pat[i], x, positions, cond=cond,
                                ac=ac, use_pallas=use_pallas)
        aux = aux + a
    return lm_logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class LayerCaches(NamedTuple):
    """Per-layer decode state for one pattern slot (or tail layer). Exactly
    one of the fields is populated, matching the slot's mixer kind; ``xattn``
    rides along with ``kv`` for cross-attention archs."""
    kv: Any = None        # PagedLayerCache (attn)
    xattn: Any = None     # StaticKVCache (attn + cross_attention)
    mamba: Any = None     # MambaState
    mlstm: Any = None     # MLSTMState
    slstm: Any = None     # SLSTMState


class ModelCache(NamedTuple):
    pattern: Any          # list over P slots; leaves stacked (R, ...)
    tail: Any             # list over remainder layers (unstacked)
    cur_pos: jax.Array    # (B,) int32 — next token position per request


def _layer_cache_shapes(cfg: ModelConfig, spec: LayerSpec, batch: int,
                        seq_len: int, policy: EvictionPolicy,
                        ccfg: CacheConfig, chunk_tokens: int = 0):
    """Slab sizing for one layer (window-aware; see DESIGN.md §3).

    ``chunk_tokens``: chunked-prefill headroom — a row transiently holds up
    to budget + chunk tokens between chunk boundaries (``append_chunk``
    never evicts mid-chunk), so the block table gets ``ceil(chunk/page)``
    extra logical slots. The pool stays ``N = B * P``, so admission still
    cannot over-commit HBM (DESIGN.md §6)."""
    window = _spec_window(cfg, spec)
    hint = seq_len if not window else min(seq_len, window + ccfg.page_size)
    pages = policy.slab_pages(ccfg, hint)
    if chunk_tokens:
        total = -(-seq_len // ccfg.page_size)
        extra = -(-chunk_tokens // ccfg.page_size)
        pages = policy._round_slab(ccfg, min(pages + extra, max(total, pages)))
    return pages


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int,
                       policy: EvictionPolicy, ccfg: CacheConfig,
                       cond=None, dtype=None, chunk_tokens: int = 0,
                       track_stats: bool = False):
    """Empty caches for decode-from-scratch (or dry-run ShapeDtype specs).
    ``chunk_tokens``: size block tables for chunked prefill (see
    :func:`_layer_cache_shapes`). ``track_stats``: attach the per-layer
    devstats telemetry vector (DESIGN.md §9); the unified step re-zeroes it
    each iteration, and :func:`collect_step_stats` sums it over layers."""
    from repro.core.paged_cache import init_layer_cache
    dt = dtype or dtype_of(ccfg.dtype)
    pat = cfg.layer_pattern()
    P, R, rem = cfg.pattern_period, cfg.full_pattern_reps, cfg.remainder_layers
    hd = cfg.resolved_head_dim

    def one(spec) -> LayerCaches:
        if spec.mixer == "attn":
            pages = _layer_cache_shapes(cfg, spec, batch, seq_len, policy,
                                        ccfg, chunk_tokens=chunk_tokens)
            kv = init_layer_cache(batch, pages, ccfg.page_size,
                                  cfg.num_kv_heads, hd, dt,
                                  track_stats=track_stats)
            xa = None
            if cfg.cross_attention:
                xa = StaticKVCache(
                    k=jnp.zeros((batch, cfg.cond_len, cfg.num_kv_heads, hd), dt),
                    v=jnp.zeros((batch, cfg.cond_len, cfg.num_kv_heads, hd), dt))
            return LayerCaches(kv=kv, xattn=xa)
        if spec.mixer == "mamba":
            return LayerCaches(mamba=mamba_mod.mamba_init_state(cfg, batch, dt))
        if spec.mixer == "mlstm":
            return LayerCaches(mlstm=xlstm_mod.mlstm_init_state(cfg, batch, dt))
        return LayerCaches(slstm=xlstm_mod.slstm_init_state(cfg, batch))

    stack = lambda c: jax.tree.map(lambda a: jnp.broadcast_to(a, (R,) + a.shape), c)
    pattern = [stack(one(pat[p])) for p in range(P)] if R > 0 else []
    tail = [one(pat[i]) for i in range(rem)]
    return ModelCache(pattern=pattern, tail=tail,
                      cur_pos=jnp.zeros((batch,), jnp.int32))


# ---------------------------------------------------------------------------
# unified mixed-batch step (chunked prefill + decode in ONE program)
# ---------------------------------------------------------------------------
# This replaces the old prefill->insert splice (forward a whole padded
# prompt into a private B=1 pool, then copy it into the batch through a
# per-slot-specialized jitted insert): requests now prefill IN PLACE, chunk
# by chunk, through the same block tables decode uses, so a long prompt
# never stalls the decode slots sharing its batch.

def _scan_recurrent(step_fn, state, init_state, h_seq, n_tok, reset_mask):
    """Run a per-token decode step over a (B, T, D) chunk. Rows past their
    ``n_tok`` freeze their state and emit zeros; ``reset_mask`` rows start
    from ``init_state`` (slot handed to a new request — note xLSTM inits
    are NOT all-zero: the max-stabilizer m starts at -inf). Chunked prefill
    of a recurrent mixer is sequential by nature — O(T) small steps; the
    attention layers are the hot path."""
    B, T = h_seq.shape[:2]
    fresh = lambda init, a: jnp.where(
        jnp.reshape(reset_mask, (B,) + (1,) * (a.ndim - 1)),
        init.astype(a.dtype), a)
    state = jax.tree.map(fresh, init_state, state)

    def body(st, xs):
        h_t, t = xs
        out, st2 = step_fn(h_t, st)
        act = t < n_tok
        keep = lambda a, b: jnp.where(
            jnp.reshape(act, (B,) + (1,) * (a.ndim - 1)), a, b)
        return jax.tree.map(keep, st2, st), jnp.where(act[:, None], out, 0.0)

    state, outs = lax.scan(body, state,
                           (jnp.swapaxes(h_seq, 0, 1), jnp.arange(T)))
    return jnp.swapaxes(outs, 0, 1), state


def _step_layer(lp, cfg, spec, x, cache: LayerCaches, positions, n_tok,
                policy: EvictionPolicy, ccfg: CacheConfig, decode_mask,
                prefill_mask, reset_mask, share_src, share_pages,
                use_pallas: bool = False, decode_splits: int = 1,
                fused_scores: bool = False, want_taps: bool = False,
                tp_axis: str | None = None):
    """One layer of the unified step. x: (B, T, D); positions: (B, T) int32
    with -1 past each row's ``n_tok``. Returns (x, LayerCaches, tap).

    ``want_taps`` (static; obs/regret.py shadow probes) makes attention
    layers also return a tap dict — the k/v written this step, the q used,
    the attention output pre-projection, and the cache's live positions AT
    ATTENTION TIME (post-append, pre-eviction). False (the default) returns
    ``tap = None`` and traces HLO identical to the pre-taps code.

    ``tp_axis`` (DESIGN.md §11): mesh axis name when the layer runs inside
    a tensor-parallel shard_map region — heads/KV-heads/d_ff arrive as
    local shards; attention and MLP/MoE outputs are psum'd here so the
    residual stream stays replicated. None (default) is the single-device
    path, traced identically to before."""
    B, T, _ = x.shape
    tap = None
    h = apply_norm(lp["norm1"], x)
    if spec.mixer == "attn":
        q, k, v = attn_mod.project_qkv(lp["attn"], cfg, h,
                                       jnp.maximum(positions, 0))
        kvc: PagedLayerCache = cache.kv
        # telemetry: the stats vector holds per-STEP counts — zero it at
        # layer entry so collect_step_stats sees only this iteration
        if kvc.stats is not None:
            kvc = kvc._replace(stats=devstats.zeros())
        # rows starting a new request free the previous occupant's pages
        # back to the shared pool before their first chunk allocates
        kvc = release_rows(kvc, reset_mask)
        # prefix sharing: an adopting row maps the source row's resident
        # prompt-prefix pages (ref_count bumped, prefill skips those tokens)
        # before its first non-shared chunk appends — DESIGN.md §7
        kvc = adopt_prefix(kvc, share_src, share_pages, enable=reset_mask)
        score = policy.write_score(k, v, positions)         # (B, T)
        kvc = append_chunk(kvc, k, v, positions, score, n_tok)
        window = _spec_window(cfg, spec)
        o, pscores = attn_mod.step_attention(
            q, kvc, q_pos=positions, window=window, use_pallas=use_pallas,
            decode_splits=decode_splits,
            want_scores=fused_scores and use_pallas, tp_axis=tp_axis)
        if want_taps:
            tap = {"k": k, "v": v, "q": q, "o": o,
                   "live_pos": kvc.pos_view()}
        # Alg.3 bookkeeping for decode rows, incremental Alg.2 compression
        # for rows that consumed a prompt chunk — disjoint masks, both
        # skipped via lax.cond when their mask is all-False. When the fused
        # epilogue ran, both hooks rank pages by the scores the attention
        # pass already produced (DESIGN.md §8).
        kvc = policy.post_write(kvc, ccfg, active=decode_mask,
                                page_scores=pscores).cache
        kvc = policy.chunk_prefill_evict(kvc, ccfg, active=prefill_mask,
                                         window=window, page_scores=pscores)
        o2 = o.reshape(B, T, -1) @ lp["attn"]["wo"]
        if tp_axis is not None:
            o2 = jax.lax.psum(o2, tp_axis)
        x = x + o2
        if cache.xattn is not None:
            hx = apply_norm(lp["norm_x"], x)
            x = x + attn_mod.cross_attention_forward(lp["xattn"], cfg, hx,
                                                     cache.xattn)
        cache = cache._replace(kv=kvc)
    elif spec.mixer == "mamba":
        m, st = _scan_recurrent(
            lambda h_t, st: mamba_mod.mamba_decode_step(lp["mamba"], cfg,
                                                        h_t, st),
            cache.mamba,
            mamba_mod.mamba_init_state(cfg, B, cache.mamba.conv.dtype),
            h, n_tok, reset_mask)
        x = x + m
        cache = cache._replace(mamba=st)
    elif spec.mixer == "mlstm":
        m, st = _scan_recurrent(
            lambda h_t, st: xlstm_mod.mlstm_decode_step(lp["mlstm"], cfg,
                                                        h_t, st),
            cache.mlstm,
            xlstm_mod.mlstm_init_state(cfg, B, cache.mlstm.conv.dtype),
            h, n_tok, reset_mask)
        x = x + m
        cache = cache._replace(mlstm=st)
    elif spec.mixer == "slstm":
        m, st = _scan_recurrent(
            lambda h_t, st: xlstm_mod.slstm_decode_step(lp["slstm"], cfg,
                                                        h_t, st),
            cache.slstm, xlstm_mod.slstm_init_state(cfg, B),
            h, n_tok, reset_mask)
        x = x + m
        cache = cache._replace(slstm=st)
    if spec.mlp == "dense":
        h2 = apply_norm(lp["norm2"], x)
        x = x + mlp_forward(lp["mlp"], cfg, h2, tp_axis=tp_axis)
    elif spec.mlp == "moe":
        # per-token dense-combine MoE: padding tokens cannot steal expert
        # capacity from live ones, so results are chunking-invariant
        h2 = apply_norm(lp["norm2"], x)
        mo = moe_forward_decode(lp["moe"], cfg, h2.reshape(B * T, -1),
                                tp_axis=tp_axis)
        x = x + mo.reshape(B, T, -1)
    return x, cache, tap


def forward_step(params, cfg: ModelConfig, tokens, n_tok, cache: ModelCache,
                 policy: EvictionPolicy, ccfg: CacheConfig, decode_mask=None,
                 prefill_mask=None, reset_mask=None, share_src=None,
                 share_pages=None, ac: Callable = Identity,
                 use_pallas: bool = False, decode_splits: int = 1,
                 fused_scores: bool = False, want_taps: bool = False,
                 tp_axis: str | None = None):
    """Unified mixed-batch step: up to T tokens per request in ONE program.

    tokens      : (B, T) int32 — row b's live tokens are tokens[b, :n_tok[b]]
                  (decode rows carry 1, prefilling rows a prompt chunk,
                  idle rows 0), appended at positions cur_pos[b] + t
    n_tok       : (B,) int32
    decode_mask : (B,) bool — rows decoding (Alg.3 post_write runs)
    prefill_mask: (B,) bool — rows that consumed a prompt chunk
                  (chunk-boundary compression runs; defaults to
                  ``n_tok > 0 & ~decode_mask``)
    reset_mask  : (B,) bool — rows starting a NEW request this step (the
                  previous occupant's pages are freed, recurrent state and
                  cur_pos reset)
    share_src   : (B,) int32 — prefix sharing: source batch row whose first
                  ``share_pages[b]`` prompt pages a resetting row adopts
                  (ref-count bump, no copy; -1 == no sharing). Only
                  meaningful on reset rows; the engine probes the source's
                  intactness (``intact_prefix_pages``) before setting this.
    share_pages : (B,) int32 — FULL prompt-prefix pages to adopt; the row's
                  cur_pos starts at ``share_pages * page_size`` and prefill
                  covers only the remaining tokens
    decode_splits: split-K factor for the Pallas decode kernel's page walk
                  (long contexts; DESIGN.md §8). Static; 1 == no split.
    fused_scores: rank PagedEviction's page eviction by the attention
                  kernels' fused score epilogue instead of the stored-score
                  reduction. Pallas-only (the flag is ignored on the jnp
                  path); numerically identical for f32 pools, so defaults
                  off only to keep pallas-vs-ref comparisons exact on int8
                  (stored scores predate quantization).

    want_taps   : static (obs/regret.py): additionally return per-attention-
                  layer taps {"k","v","q","o","live_pos"} — pattern-slot
                  taps stacked over reps — plus the step's ``positions``.
                  False leaves returns AND traced HLO unchanged.
    tp_axis     : static (DESIGN.md §11): mesh axis name when this step is
                  traced inside a tensor-parallel shard_map region. The
                  caller must pass weight/pool shards consistent with
                  ``sharding.rules.tp_*_specs`` and a policy built with
                  ``get_policy(name, tp_axis=...)``; layer outputs psum
                  over the axis so the residual stream (and hence logits
                  and sampling) is replicated on every shard.

    Returns (logits (B, vocab) at each row's last live token, cache), plus
    the taps dict when ``want_taps``. Rows with n_tok == 0 return logits of
    stale garbage — callers mask.
    """
    x = embed_tokens(params, cfg, tokens)                   # (B, T, D)
    B, T = x.shape[0], x.shape[1]
    if decode_mask is None:
        decode_mask = jnp.zeros((B,), bool)
    if prefill_mask is None:
        prefill_mask = (n_tok > 0) & ~decode_mask
    if reset_mask is None:
        reset_mask = jnp.zeros((B,), bool)
    if share_src is None:
        share_src = jnp.full((B,), -1, jnp.int32)
    if share_pages is None:
        share_pages = jnp.zeros((B,), jnp.int32)
    cur_pos = jnp.where(reset_mask, share_pages * ccfg.page_size,
                        cache.cur_pos)
    positions = cur_pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    positions = jnp.where(jnp.arange(T)[None, :] < n_tok[:, None],
                          positions, -1)
    pat = cfg.layer_pattern()
    P = cfg.pattern_period

    def rep_body(x, xs):
        slot_params, slot_caches = xs
        new_caches, slot_taps = [], []
        for p in range(P):
            x, c, tp = _step_layer(slot_params[p], cfg, pat[p], ac(x),
                                   slot_caches[p], positions, n_tok, policy,
                                   ccfg, decode_mask, prefill_mask,
                                   reset_mask, share_src, share_pages,
                                   use_pallas, decode_splits, fused_scores,
                                   want_taps, tp_axis)
            new_caches.append(c)
            slot_taps.append(tp)
        if want_taps:
            return x, (tuple(new_caches), tuple(slot_taps))
        return x, tuple(new_caches)

    pattern_taps: list = []
    if params["pattern"]:
        x, ys = lax.scan(
            rep_body, x, (tuple(params["pattern"]), tuple(cache.pattern)))
        if want_taps:
            pattern_caches, pattern_taps = list(ys[0]), list(ys[1])
        else:
            pattern_caches = list(ys)
    else:
        pattern_caches = []
    tail_caches, tail_taps = [], []
    for i, lp in enumerate(params["tail"]):
        x, c, tp = _step_layer(lp, cfg, pat[i], ac(x), cache.tail[i],
                               positions, n_tok, policy, ccfg, decode_mask,
                               prefill_mask, reset_mask, share_src,
                               share_pages, use_pallas, decode_splits,
                               fused_scores, want_taps, tp_axis)
        tail_caches.append(c)
        tail_taps.append(tp)
    last = jnp.maximum(n_tok - 1, 0)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    logits = lm_logits(params, cfg, x_last)
    out_cache = ModelCache(pattern=pattern_caches, tail=tail_caches,
                           cur_pos=cur_pos + n_tok)
    if want_taps:
        taps = {"pattern": pattern_taps, "tail": tail_taps,
                "positions": positions}
        return logits, out_cache, taps
    return logits, out_cache


def collect_step_stats(cache: ModelCache):
    """Sum every attention layer's devstats vector -> (devstats.NSTATS,)
    int32, or None when the caches don't track stats. Pure jnp — the engine
    calls this INSIDE its jitted step so the whole telemetry path costs one
    tiny reduction plus one (NSTATS,) transfer per step (DESIGN.md §9).
    Call AFTER the step (each layer zeroes its vector at entry, so the sum
    is exactly this iteration's events across the stack)."""
    vecs = []
    for lc in cache.pattern:
        if lc.kv is None or lc.kv.stats is None:
            continue
        vecs.append(jnp.sum(lc.kv.stats, axis=0))   # stats stacked (R, NSTATS)
    for lc in cache.tail:
        if lc.kv is None or lc.kv.stats is None:
            continue
        vecs.append(lc.kv.stats)
    if not vecs:
        return None
    out = vecs[0]
    for v in vecs[1:]:
        out = out + v
    return out


def intact_prefix_pages(cache: ModelCache, row) -> jax.Array:
    """() int32 — how many leading FULL prompt pages of batch row ``row``
    are intact in EVERY attention layer's cache (min over layers; stacked
    pattern slots vmapped over their repetitions). This is the device half
    of the prefix-sharing admission probe: the scheduler's radix index says
    which resident row textually shares a prompt prefix; this says how much
    of that prefix actually survives eviction. 0 when the model has no
    attention layers (recurrent state cannot be adopted page-wise)."""
    runs = []
    for lc in cache.pattern:
        if lc.kv is None:
            continue
        per_rep = jax.vmap(lambda c: row_intact_prefix_pages(c, row))(lc.kv)
        runs.append(jnp.min(per_rep))
    for lc in cache.tail:
        if lc.kv is None:
            continue
        runs.append(row_intact_prefix_pages(lc.kv, row))
    if not runs:
        return jnp.zeros((), jnp.int32)
    out = runs[0]
    for r in runs[1:]:
        out = jnp.minimum(out, r)
    return out


# ---------------------------------------------------------------------------
# prefill forward (build caches)
# ---------------------------------------------------------------------------

def _prefill_layer(lp, cfg, spec, x, positions, valid, cond, policy, ccfg,
                   seq_len_hint, ac: Callable = Identity,
                   use_pallas: bool = False) -> tuple:
    """Layer forward that also produces its decode cache."""
    x, aux, extras = layer_forward(
        lp, cfg, spec, x, positions, cond=cond, ac=ac,
        return_kv=(spec.mixer == "attn"), return_state=(spec.mixer != "attn"),
        use_pallas=use_pallas)
    if spec.mixer == "attn":
        k, v = extras
        window = _spec_window(cfg, spec)
        hint = seq_len_hint if not window else min(
            seq_len_hint, window + ccfg.page_size)
        kv_valid = valid
        if window:
            # windowed layers never attend past the window again: drop
            # out-of-window tokens at paging time (keeps slab small)
            cur = jnp.max(jnp.where(valid, positions, -1), axis=-1, keepdims=True)
            kv_valid = valid & (positions > cur - window)
        cache = compress_and_page(k, v, positions, kv_valid, policy, ccfg,
                                  seq_len_hint=hint,
                                  cache_dtype=dtype_of(ccfg.dtype))
        xa = None
        if cond is not None and "xattn" in lp:
            xa = attn_mod.make_cross_cache(lp["xattn"], cfg, cond)
        return x, aux, LayerCaches(kv=cache, xattn=xa)
    if spec.mixer == "mamba":
        return x, aux, LayerCaches(mamba=extras)
    if spec.mixer == "mlstm":
        return x, aux, LayerCaches(mlstm=extras)
    return x, aux, LayerCaches(slstm=extras)


def forward_prefill(params, cfg: ModelConfig, tokens, policy: EvictionPolicy,
                    ccfg: CacheConfig, cond=None, valid=None,
                    ac: Callable = Identity, total_seq_hint: int | None = None,
                    use_pallas: bool = False):
    """Process the prompt, compress each attn layer's KV per Alg.2, return
    (last-token logits, ModelCache).

    ``total_seq_hint``: expected prompt+generation length — sizes the page
    slabs so decode can continue in-place (defaults to the prompt length)."""
    x = embed_tokens(params, cfg, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if valid is None:
        valid = jnp.ones((B, S), bool)
    positions = jnp.where(valid, positions, -1)
    pat = cfg.layer_pattern()
    P = cfg.pattern_period
    hint = total_seq_hint or S

    def rep_body(carry, slot_params):
        x, aux = carry
        caches = []
        for p in range(P):
            x, a, c = _prefill_layer(slot_params[p], cfg, pat[p], x, positions,
                                     valid, cond, policy, ccfg, hint, ac=ac,
                                     use_pallas=use_pallas)
            aux = aux + a
            caches.append(c)
        return (x, aux), tuple(caches)

    carry = (x, jnp.zeros((), jnp.float32))
    if params["pattern"]:
        carry, pattern_caches = lax.scan(rep_body, carry, tuple(params["pattern"]))
        pattern_caches = list(pattern_caches)
    else:
        pattern_caches = []
    x, aux = carry
    tail_caches = []
    for i, lp in enumerate(params["tail"]):
        x, a, c = _prefill_layer(lp, cfg, pat[i], x, positions, valid, cond,
                                 policy, ccfg, hint, ac=ac,
                                 use_pallas=use_pallas)
        aux = aux + a
        tail_caches.append(c)

    # last valid token's hidden state -> next-token logits
    last_idx = jnp.maximum(jnp.sum(valid.astype(jnp.int32), axis=-1) - 1, 0)
    x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(params, cfg, x_last)
    next_pos = jnp.sum(valid.astype(jnp.int32), axis=-1)
    cache = ModelCache(pattern=pattern_caches, tail=tail_caches,
                       cur_pos=next_pos)
    return logits, cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _decode_layer(lp, cfg, spec, x, cache: LayerCaches, cur_pos,
                  policy: EvictionPolicy, ccfg: CacheConfig, active,
                  use_pallas: bool = False, decode_splits: int = 1,
                  fused_scores: bool = False):
    """One layer, one token. x: (B, D). Returns (x, LayerCaches)."""
    h = apply_norm(lp["norm1"], x)
    if spec.mixer == "attn":
        q, k, v = attn_mod.decode_project_qkv(lp["attn"], cfg, h, cur_pos)
        kvc: PagedLayerCache = cache.kv
        if kvc.stats is not None:
            kvc = kvc._replace(stats=devstats.zeros())
        score = policy.write_score(k, v, cur_pos)
        # lazy rollover: chunked prefill parks the head at cur_off ==
        # page_size when a chunk ends exactly on a page boundary — the
        # first decode write then allocates the working page (post_write
        # keeps rolling eagerly afterwards, so this is a no-op mid-stream)
        kvc = chunk_rollover(kvc, active & (kvc.cur_off >= kvc.page_size))
        kvc = write_token(kvc, k, v, cur_pos, score, active=active)
        window = _spec_window(cfg, spec)
        o, pscores = attn_mod.decode_attention(
            q, kvc, cur_pos=cur_pos, window=window, use_pallas=use_pallas,
            num_splits=decode_splits,
            want_scores=fused_scores and use_pallas)
        outcome = policy.post_write(kvc, ccfg, active=active,
                                    page_scores=pscores)
        kvc = outcome.cache
        B = x.shape[0]
        o = o.reshape(B, -1) @ lp["attn"]["wo"]
        x = x + o
        if cache.xattn is not None:
            hx = apply_norm(lp["norm_x"], x[:, None, :])
            o2 = attn_mod.cross_attention_forward(lp["xattn"], cfg, hx,
                                                  cache.xattn)
            x = x + o2[:, 0]
        cache = cache._replace(kv=kvc)
    elif spec.mixer == "mamba":
        m, st = mamba_mod.mamba_decode_step(lp["mamba"], cfg, h, cache.mamba)
        x = x + m
        cache = cache._replace(mamba=st)
    elif spec.mixer == "mlstm":
        m, st = xlstm_mod.mlstm_decode_step(lp["mlstm"], cfg, h, cache.mlstm)
        x = x + m
        cache = cache._replace(mlstm=st)
    elif spec.mixer == "slstm":
        m, st = xlstm_mod.slstm_decode_step(lp["slstm"], cfg, h, cache.slstm)
        x = x + m
        cache = cache._replace(slstm=st)
    if spec.mlp == "dense":
        h2 = apply_norm(lp["norm2"], x)
        x = x + mlp_forward(lp["mlp"], cfg, h2)
    elif spec.mlp == "moe":
        h2 = apply_norm(lp["norm2"], x)
        x = x + moe_forward_decode(lp["moe"], cfg, h2)
    return x, cache


def decode_step(params, cfg: ModelConfig, tokens, cache: ModelCache,
                policy: EvictionPolicy, ccfg: CacheConfig, active=None,
                use_pallas: bool = False, ac: Callable = Identity,
                decode_splits: int = 1, fused_scores: bool = False):
    """One decode step. tokens: (B,) [or (B, K) audio] -> (logits, cache).
    ``decode_splits`` / ``fused_scores``: see :func:`forward_step`."""
    if cfg.num_codebooks > 1:
        # tokens: (B, K); embed: (K, V, D)
        per_cb = jax.vmap(lambda emb, tok: jnp.take(emb, tok, axis=0),
                          in_axes=(0, 1))(params["embed"], tokens)  # (K, B, D)
        x = jnp.sum(per_cb, axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)        # (B, D)
    B = x.shape[0]
    if active is None:
        active = jnp.ones((B,), bool)
    cur_pos = cache.cur_pos
    pat = cfg.layer_pattern()
    P = cfg.pattern_period

    def rep_body(x, xs):
        slot_params, slot_caches = xs
        new_caches = []
        for p in range(P):
            x, c = _decode_layer(slot_params[p], cfg, pat[p], ac(x),
                                 slot_caches[p], cur_pos, policy, ccfg,
                                 active, use_pallas, decode_splits,
                                 fused_scores)
            new_caches.append(c)
        return x, tuple(new_caches)

    if params["pattern"]:
        x, pattern_caches = lax.scan(
            rep_body, x, (tuple(params["pattern"]), tuple(cache.pattern)))
        pattern_caches = list(pattern_caches)
    else:
        pattern_caches = []
    tail_caches = []
    for i, lp in enumerate(params["tail"]):
        x, c = _decode_layer(lp, cfg, pat[i], ac(x), cache.tail[i], cur_pos,
                             policy, ccfg, active, use_pallas, decode_splits,
                             fused_scores)
        tail_caches.append(c)
    logits = lm_logits(params, cfg, x)
    new_pos = jnp.where(active, cur_pos + 1, cur_pos)
    return logits, ModelCache(pattern=pattern_caches, tail=tail_caches,
                              cur_pos=new_pos)
