"""Shared model substrate: norms, RoPE, embeddings, initializers, and a
memory-bounded blocked causal attention (online softmax) used for long
prefill sequences.

Everything is functional: ``init_*`` returns a params pytree, ``apply``-style
functions are pure. No flax/haiku — params are plain nested dicts so they
shard transparently under pjit.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16, "int8": jnp.int8}


def dtype_of(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg_norm: str, dim: int, dtype):
    if cfg_norm == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_norm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(x, scale, eps: float = 1e-6):
    """qk-norm: RMS-normalize over the head dim. x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim) or (..., heads, head_dim) w/ scalar pos.
    positions broadcastable to x's seq axes. Rotates pairs (x[2i], x[2i+1])."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def soft_cap(logits, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


# ---------------------------------------------------------------------------
# blocked causal attention (pure-jnp flash-style; the memory-safe default
# for long sequences; the Pallas kernel in repro.kernels is the fast path)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B, Sq, KV, G, hd), k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32))


def full_causal_attention(q, k, v, *, q_positions, kv_positions, window: int = 0,
                          sink_keep: int = 0, scale: float | None = None):
    """Reference causal (optionally windowed) GQA attention, materializing the
    (Sq, Sk) score matrix. Use only for modest S; see blocked variant below.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).
    window>0: attend only to kv with q_pos - window < kv_pos (plus causal).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = _gqa_scores(qg, k) * scale                    # (B,KV,G,Sq,Sk)
    mask = kv_positions[:, None, :] <= q_positions[:, :, None]   # (B,Sq,Sk)
    if window:
        mask &= kv_positions[:, None, :] > (q_positions[:, :, None] - window)
        if sink_keep:
            mask |= (kv_positions[:, None, :] < sink_keep) & (
                kv_positions[:, None, :] <= q_positions[:, :, None])
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # rows with no valid kv
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def blocked_causal_attention(q, k, v, *, q_positions, kv_positions,
                             window: int = 0, q_chunk: int = 1024,
                             kv_chunk: int = 1024, scale: float | None = None):
    """Memory-bounded causal GQA attention via online softmax over kv chunks.

    Never materializes more than (q_chunk, kv_chunk) scores per head. Used
    for 32k+ prefill where the full score matrix would not fit HBM.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, q_chunk, Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    qg = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd)
    qp = q_positions.reshape(B, nq, q_chunk)
    kp = kv_positions.reshape(B, nk, kv_chunk)

    def q_block(carry, qi):
        qb = qg[:, qi]                                   # (B,qc,KV,G,hd)
        qpb = qp[:, qi]                                  # (B,qc)

        def kv_block(state, ki):
            m, l, acc = state
            kb, vb, kpb = kc[:, ki], vc[:, ki], kp[:, ki]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            mask = kpb[:, None, :] <= qpb[:, :, None]
            if window:
                mask &= kpb[:, None, :] > (qpb[:, :, None] - window)
            s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard: fully-masked rows keep m=-inf; exp(-inf - -inf) -> nan
            safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - safe_m[..., None])
            corr = jnp.exp(jnp.where(jnp.isneginf(m), 0.0, m) - safe_m)
            corr = jnp.where(jnp.isneginf(m), 0.0, corr)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, KV, G, q_chunk), jnp.float32),
            jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32),
        )
        # flash-backward memory profile: remat each kv block so autodiff
        # saves only the (m, l, acc) carries, recomputing the (qc, kc) score
        # block in the backward pass instead of storing it (§Perf mixtral
        # iter 3: -100+GB/device of scan residuals for ~+25% attention flops)
        (m, l, acc), _ = lax.scan(
            jax.checkpoint(kv_block, prevent_cse=False), init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,KV,G,qc,hd)
        out = jnp.moveaxis(out, 3, 1).reshape(B, q_chunk, KV * G, hd)
        return carry, out.astype(q.dtype)

    _, outs = lax.scan(q_block, 0, jnp.arange(nq))        # (nq,B,qc,H,hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


def causal_attention(q, k, v, *, q_positions, kv_positions, window: int = 0,
                     scale: float | None = None, blocked_threshold: int = 8192):
    """Dispatch: full matrix for short sequences, blocked for long ones."""
    if q.shape[1] * k.shape[1] <= blocked_threshold * blocked_threshold // 16 \
            or q.shape[1] < 1024:
        return full_causal_attention(q, k, v, q_positions=q_positions,
                                     kv_positions=kv_positions, window=window,
                                     scale=scale)
    q_chunk = min(1024, q.shape[1])
    kv_chunk = min(1024, k.shape[1])
    return blocked_causal_attention(q, k, v, q_positions=q_positions,
                                    kv_positions=kv_positions, window=window,
                                    q_chunk=q_chunk, kv_chunk=kv_chunk,
                                    scale=scale)
