"""GQA attention layer — contiguous (train/prefill) and paged (decode) paths.

Supports: QKV bias (qwen), qk-norm (chameleon/gemma3), sliding-window
(mixtral) and local/global interleave (gemma3), cross-attention to a static
conditioning cache (musicgen), logit soft-capping.

Decode attends against a :class:`PagedLayerCache` via either the pure-jnp
reference (``repro.kernels.ref``-equivalent, used on CPU) or the Pallas
paged-attention kernel (``repro.kernels.ops``, the TPU hot path).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.paged_cache import PagedLayerCache
from repro.models.common import (
    apply_rope,
    causal_attention,
    dense_init,
    rms_head_norm,
)


class StaticKVCache(NamedTuple):
    """Non-growing KV over conditioning (cross-attention); exempt from
    eviction — it is O(cond_len) and shared across all decode steps."""
    k: jax.Array  # (B, Sc, KV, hd)
    v: jax.Array  # (B, Sc, KV, hd)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    hd = cfg.resolved_head_dim
    D, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, KV * hd, dt),
        "wv": dense_init(ks[2], D, KV * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def project_qkv(params, cfg: ModelConfig, x, positions, rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,hd), k, v (B,S,KV,hd). RoPE + qk-norm applied.

    Head counts come from the projection widths, not the config: under
    tensor parallelism (shard_map manual region) wq/wk/wv are column
    shards holding H/tp and KV/tp heads, and the reshape must follow the
    LOCAL width. At TP=1 the two are identical."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    H, KV = q.shape[-1] // hd, k.shape[-1] // hd
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if "q_norm" in params:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# contiguous path (train / prefill)
# ---------------------------------------------------------------------------

def attention_forward(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                      return_kv: bool = False, use_pallas: bool = False):
    """Causal self-attention over a contiguous sequence.

    Returns (out (B,S,D), (k, v) post-rope if return_kv else None).
    ``use_pallas``: route through the Pallas flash kernel (TPU hot path;
    proper triangle/window block skipping) when the shape is tileable —
    falls back to the blocked jnp path otherwise.
    """
    q, k, v = project_qkv(params, cfg, x, positions)
    window = 0
    if spec.attn_kind == "swa":
        window = cfg.sliding_window
    elif spec.attn_kind == "local":
        window = cfg.local_window
    B, S = x.shape[:2]
    hd = cfg.resolved_head_dim
    if use_pallas and S % 128 == 0 and hd % 8 == 0:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, window=window)
    else:
        out = causal_attention(q, k, v, q_positions=positions,
                               kv_positions=positions, window=window)
    out = out.reshape(B, S, -1) @ params["wo"]
    return out, ((k, v) if return_kv else None)


def cross_attention_forward(params, cfg: ModelConfig, x, cache: StaticKVCache):
    """Cross-attention to static conditioning KV (no causality, no rope)."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   cache.k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, cache.v.astype(jnp.float32))
    o = o.reshape(B, S, H * hd).astype(x.dtype)
    return o @ params["wo"]


def make_cross_cache(params, cfg: ModelConfig, cond) -> StaticKVCache:
    """cond: (B, Sc, D) conditioning embeddings -> static KV."""
    B, Sc, D = cond.shape
    hd = cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    k = (cond @ params["wk"]).reshape(B, Sc, KV, hd)
    v = (cond @ params["wv"]).reshape(B, Sc, KV, hd)
    return StaticKVCache(k=k, v=v)


# ---------------------------------------------------------------------------
# paged decode path
# ---------------------------------------------------------------------------

def paged_attention_ref(q, cache: PagedLayerCache, *, cur_pos, window: int = 0,
                        sink_keep: int = 0, scale: float | None = None,
                        soft_cap: float = 0.0):
    """Single-token GQA attention over a paged cache (pure-jnp oracle).

    q: (B, H, hd) — the current token's query (RoPE'd at cur_pos).
    cur_pos: (B,) int32 current position (new token's position).
    Masks: invalid slots (pos<0), future slots (pos>cur_pos), and for
    windowed layers pos <= cur_pos - window (sinks exempt).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    P, page, KV = cache.num_pages, cache.page_size, cache.k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    # gather the shared pool into this request's logical view (the pure-jnp
    # oracle materializes the indirection the Pallas kernel streams)
    kf = cache.k_view().reshape(B, P * page, KV, hd)
    vf = cache.v_view().reshape(B, P * page, KV, hd)
    pos = cache.pos_view().reshape(B, P * page)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if soft_cap:
        s = jnp.tanh(s / soft_cap) * soft_cap
    mask = (pos >= 0) & (pos <= cur_pos[:, None])
    if window:
        in_win = pos > (cur_pos[:, None] - window)
        if sink_keep:
            in_win |= pos < sink_keep
        mask &= in_win
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def paged_attention_chunk_ref(q, cache: PagedLayerCache, *, q_pos,
                              window: int = 0, scale: float | None = None):
    """Chunked-prefill GQA attention over a paged cache (the unified-step
    CPU path). q: (B, T, H, hd) — a contiguous chunk of queries, RoPE'd at
    q_pos; q_pos: (B, T) int32, -1 marks padding queries (rows shorter
    than the chunk), which return zeros. The chunk's own K/V must already
    be appended to the pool (write-then-attend), so intra-chunk causality
    is just pos <= q_pos. Returns (B, T, H, hd).

    Gathers the pool into the request view and delegates to the single
    chunk-attention oracle in ``kernels/ref.py`` — one copy of the
    masking/causality logic, shared with the Pallas kernel's parity tests.
    """
    from repro.kernels.ref import paged_prefill_attention_ref

    B, T, H, hd = q.shape
    KV = cache.k.shape[2]
    G = H // KV
    kg = jnp.moveaxis(cache.k_view(), 3, 1)        # (B, KV, P, page, hd)
    vg = jnp.moveaxis(cache.v_view(), 3, 1)
    out = paged_prefill_attention_ref(q.reshape(B, T, KV, G, hd), kg, vg,
                                      cache.pos_view(), q_pos,
                                      window=window, scale=scale)
    return out.reshape(B, T, H, hd)


def decode_attention(q, cache: PagedLayerCache, *, cur_pos, window: int = 0,
                     use_pallas: bool = False, num_splits: int = 1,
                     want_scores: bool = False, tp_axis: str | None = None):
    """Single-token attention dispatch: Pallas split-K decode kernel or the
    pure-jnp oracle. q: (B, H, hd). Returns ``(o, page_scores)`` where
    page_scores is the fused eviction-score epilogue (B, P) when
    ``want_scores`` and the kernel ran, else None (callers fall back to the
    stored-score path). ``num_splits`` partitions the page walk
    (DESIGN.md §8); the oracle ignores it (math is split-invariant).
    ``tp_axis``: mesh axis the KV heads are sharded over — the fused score
    epilogue pmeans its per-head norms across it (attention itself needs no
    collective: each query group attends only its own local KV heads)."""
    if use_pallas:
        from repro.kernels.ops import paged_attention
        if want_scores:
            return paged_attention(q, cache, cur_pos=cur_pos, window=window,
                                   num_splits=num_splits, return_scores=True,
                                   tp_axis=tp_axis)
        return paged_attention(q, cache, cur_pos=cur_pos, window=window,
                               num_splits=num_splits), None
    return paged_attention_ref(q, cache, cur_pos=cur_pos, window=window), None


def step_attention(q, cache: PagedLayerCache, *, q_pos, window: int = 0,
                   use_pallas: bool = False, decode_splits: int = 1,
                   want_scores: bool = False, tp_axis: str | None = None):
    """Unified-step attention dispatch (the hot-path switch that used to
    live inline in ``transformer._step_layer``). q: (B, T, H, hd), q_pos:
    (B, T). T == 1 routes to the split-K decode kernel — one query row
    shouldn't pay the chunk kernel's tile shape, and the split-K walk
    shortens the serial chain; otherwise the G-fold chunked-prefill kernel
    (each K/V page DMA'd once per KV-head group) or the jnp chunk oracle.
    Returns ``(o (B, T, H, hd), page_scores (B, P) | None)``."""
    B, T = q.shape[:2]
    if use_pallas and T == 1:
        o, ps = decode_attention(q[:, 0], cache, cur_pos=q_pos[:, 0],
                                 window=window, use_pallas=True,
                                 num_splits=decode_splits,
                                 want_scores=want_scores, tp_axis=tp_axis)
        return o[:, None], ps
    if use_pallas:
        from repro.kernels.ops import paged_prefill_attention
        if want_scores:
            return paged_prefill_attention(q, cache, q_pos=q_pos,
                                           window=window, return_scores=True,
                                           tp_axis=tp_axis)
        return paged_prefill_attention(q, cache, q_pos=q_pos,
                                       window=window), None
    return paged_attention_chunk_ref(q, cache, q_pos=q_pos,
                                     window=window), None


def decode_project_qkv(params, cfg: ModelConfig, x, cur_pos):
    """x: (B, D) single token -> q (B,H,hd), k, v (B,KV,hd), RoPE at cur_pos.

    Head counts derive from the projection widths (shard-local under TP,
    matching :func:`project_qkv`)."""
    B, D = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    H, KV = q.shape[-1] // hd, k.shape[-1] // hd
    q = q.reshape(B, H, hd)
    k = k.reshape(B, KV, hd)
    v = v.reshape(B, KV, hd)
    if "q_norm" in params:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    # apply_rope expects (..., seq, heads, hd); lift to seq=1 then squeeze
    q = apply_rope(q[:, None], cur_pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], cur_pos[:, None], cfg.rope_theta)[:, 0]
    return q, k, v
