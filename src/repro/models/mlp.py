"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init


def init_mlp(key, cfg: ModelConfig):
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, cfg.d_ff, dt),
        "w_up": dense_init(k2, cfg.d_model, cfg.d_ff, dt),
        "w_down": dense_init(k3, cfg.d_ff, cfg.d_model, dt),
    }


def mlp_forward(params, cfg: ModelConfig, x):
    act = activation(cfg.act)
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]
