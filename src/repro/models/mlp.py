"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init


def init_mlp(key, cfg: ModelConfig):
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, cfg.d_ff, dt),
        "w_up": dense_init(k2, cfg.d_model, cfg.d_ff, dt),
        "w_down": dense_init(k3, cfg.d_ff, cfg.d_model, dt),
    }


def mlp_forward(params, cfg: ModelConfig, x, tp_axis=None):
    """Gated MLP. Under tensor parallelism ``d_ff`` is sharded over
    ``tp_axis`` (w_gate/w_up column-parallel, w_down row-parallel); the
    partial output is psum'd so every shard holds the full activation."""
    act = activation(cfg.act)
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    out = h @ params["w_down"]
    if tp_axis is not None:
        out = jax.lax.psum(out, tp_axis)
    return out
