"""Pure-JAX model substrate."""
from repro.models.transformer import (
    LayerCaches,
    ModelCache,
    decode_step,
    embed_tokens,
    forward_prefill,
    forward_step,
    forward_train,
    init_decode_caches,
    init_model,
    lm_logits,
)
from repro.models.multimodal import input_specs, make_inputs

__all__ = [
    "LayerCaches", "ModelCache", "decode_step", "embed_tokens",
    "forward_prefill", "forward_step", "forward_train", "init_decode_caches",
    "init_model", "lm_logits", "input_specs", "make_inputs",
]
