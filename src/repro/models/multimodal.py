"""Modality frontend stubs + input spec builders.

Per the assignment, ``[vlm]`` and ``[audio]`` entries cover the transformer
BACKBONE only; the modality frontends are stubs:

  chameleon (early fusion): the VQ-GAN image tokenizer is the stub. Image
    patches arrive as *discrete token ids inside the shared vocab* (that is
    what early fusion means) — the backbone is modality-agnostic, so
    ``input_specs`` simply provides mixed text+image token ids.
  musicgen: the EnCodec audio codec and the T5 text encoder are stubs.
    ``input_specs`` provides (B, K, S) codebook token ids plus precomputed
    conditioning embeddings (B, cond_len, d_model) for cross-attention.

``make_inputs`` produces concrete random inputs (smoke tests / examples);
``input_specs`` produces jax.ShapeDtypeStruct stand-ins (dry-run lowering,
no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import dtype_of


def token_shape(cfg: ModelConfig, batch: int, seq_len: int) -> tuple:
    if cfg.num_codebooks > 1:
        return (batch, cfg.num_codebooks, seq_len)
    return (batch, seq_len)


def decode_token_shape(cfg: ModelConfig, batch: int) -> tuple:
    if cfg.num_codebooks > 1:
        return (batch, cfg.num_codebooks)
    return (batch,)


def make_inputs(key, cfg: ModelConfig, batch: int, seq_len: int):
    """Concrete random inputs: dict(tokens=..., cond=... or None)."""
    kt, kc = jax.random.split(key)
    tokens = jax.random.randint(kt, token_shape(cfg, batch, seq_len), 0,
                                cfg.vocab_size, jnp.int32)
    cond = None
    if cfg.cross_attention:
        cond = jax.random.normal(
            kc, (batch, cfg.cond_len, cfg.d_model), jnp.float32
        ).astype(dtype_of(cfg.dtype))
    return {"tokens": tokens, "cond": cond}


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, for_decode=False):
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    B = shape.global_batch
    if for_decode:
        tokens = jax.ShapeDtypeStruct(decode_token_shape(cfg, B), jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct(token_shape(cfg, B, shape.seq_len), jnp.int32)
    cond = None
    if cfg.cross_attention:
        cond = jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.d_model),
                                    dtype_of(cfg.dtype))
    return {"tokens": tokens, "cond": cond}
