"""Aggregate dry-run artifacts into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints
the per-(arch x shape x policy) roofline terms + dominant bottleneck. This
is the source for EXPERIMENTS.md §Roofline.

Also the before/after gate for kernel perf work: ``--diff OLD_DIR NEW_DIR``
matches artifacts between two dry-run dirs on (arch, shape, mesh, policy,
variant) and prints per-term deltas, so a kernel PR can show its roofline
movement from two artifact snapshots (DESIGN.md §8).

``--obs TRACE.jsonl`` joins the table with MEASURED step timings from a
serving trace (repro.obs.trace schema): per step kind it prints wall-time
percentiles, tokens/step and pool churn, and for roofline rows of the
same policy the measured-vs-modelled step-time ratio (DESIGN.md §9)."""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

COLS = ["arch", "shape", "mesh", "policy", "compute_s", "memory_s",
        "collective_s", "dominant", "useful_flops_ratio"]

_HEAD = ("| arch | shape | mesh | policy | variant | compute (s) | "
         "memory (s) | collective (s) | dominant | useful |\n"
         "| --- | --- | --- | --- | --- | --- | --- | --- | --- | --- |")


def load_rows(art_dir: str = ART_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def _variant(r: dict) -> str:
    notes = r.get("notes", "")
    tags = []
    if "zero1=True" in notes:
        tags.append("zero1")
    if "cache_dtype=int8" in notes:
        tags.append("int8")
    return "+".join(tags) or "-"


def _key(r: dict) -> tuple:
    return (r["arch"], r["shape"], r["mesh"], r["policy"], _variant(r))


def fmt_row(r: dict) -> str:
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{_variant(r)} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} |")


def markdown_table(rows: list[dict]) -> str:
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r["mesh"], r["policy"], _variant(r)))
    return "\n".join([_HEAD] + [fmt_row(r) for r in rows])


def diff_rows(old_rows: list[dict], new_rows: list[dict]) -> list[dict]:
    """Match artifacts on (arch, shape, mesh, policy, variant); return one
    record per matched pair with per-term before/after and ratios."""
    old = {_key(r): r for r in old_rows}
    out = []
    for r in new_rows:
        o = old.get(_key(r))
        if o is None:
            continue
        rec = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
               "policy": r["policy"], "variant": _variant(r)}
        for term in ("compute_s", "memory_s", "collective_s"):
            rec[f"{term}_before"] = o[term]
            rec[f"{term}_after"] = r[term]
            rec[f"{term}_ratio"] = (r[term] / o[term]) if o[term] else 1.0
        rec["dominant_before"] = o["dominant"]
        rec["dominant_after"] = r["dominant"]
        out.append(rec)
    return out


def diff_table(recs: list[dict]) -> str:
    head = ("| arch | shape | mesh | policy | variant | compute | memory | "
            "collective | dominant |\n"
            "| --- | --- | --- | --- | --- | --- | --- | --- | --- |")
    lines = [head]
    for d in recs:
        cells = []
        for term in ("compute_s", "memory_s", "collective_s"):
            cells.append(f"{d[term + '_before']:.2e} -> "
                         f"{d[term + '_after']:.2e} "
                         f"({d[term + '_ratio']:.2f}x)")
        dom = d["dominant_before"]
        if d["dominant_after"] != dom:
            dom += f" -> {d['dominant_after']}"
        lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                     f"{d['policy']} | {d['variant']} | " +
                     " | ".join(cells) + f" | **{dom}** |")
    return "\n".join(lines)


def run(quick: bool = False, art_dir: str = ART_DIR):
    rows = load_rows(art_dir)
    if not rows:
        # degrade loudly, not silently: say why the table is empty, print
        # the (empty) table anyway so downstream parsers see the schema
        reason = ("artifact dir missing" if not os.path.isdir(art_dir)
                  else "artifact dir empty")
        print(f"  roofline: no dry-run artifacts ({reason}: {art_dir}) — "
              "run `python -m repro.launch.dryrun --all` to generate them")
        print(_HEAD)
        print("  roofline,artifacts=0,dominants={}")
        return []
    print(markdown_table(rows))
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"  roofline,artifacts={len(rows)},dominants={doms}")
    return rows


def run_diff(old_dir: str, new_dir: str) -> list[dict]:
    old_rows, new_rows = load_rows(old_dir), load_rows(new_dir)
    if not old_rows or not new_rows:
        which = old_dir if not old_rows else new_dir
        print(f"  roofline-diff: no artifacts in {which} — nothing to diff")
        return []
    recs = diff_rows(old_rows, new_rows)
    if not recs:
        print("  roofline-diff: no matching (arch, shape, mesh, policy, "
              "variant) rows between the two dirs")
        return []
    print(diff_table(recs))
    print(f"  roofline-diff,matched={len(recs)},"
          f"unmatched={len(new_rows) - len(recs)}")
    return recs


def _pct(xs: list[float], q: float) -> float:
    if len(xs) == 1:
        return xs[0]
    qs = statistics.quantiles(sorted(xs), n=100, method="inclusive")
    return qs[min(98, max(0, int(round(q * 100)) - 1))]


def trace_summary(events: list[dict]) -> list[dict]:
    """One row per step kind: wall-time percentiles + per-step averages of
    the device pool counters carried in the trace."""
    by_kind: dict = {}
    for ev in events:
        # schema v2 traces interleave "event" (page lineage) and "probe"
        # (eviction regret) records with the per-step records; the timing
        # summary only consumes steps. v1 files carry no "rec" field and
        # are all steps.
        if ev.get("rec", "step") != "step":
            continue
        if ev["kind"] == "idle":
            continue
        by_kind.setdefault(ev["kind"], []).append(ev)
    rows = []
    for kind in ("prefill", "mixed", "decode"):
        evs = by_kind.get(kind)
        if not evs:
            continue
        ts = [e["step_ms"] for e in evs]
        n = len(evs)
        rows.append({
            "kind": kind, "steps": n,
            "step_ms_p50": _pct(ts, 0.50), "step_ms_p90": _pct(ts, 0.90),
            "step_ms_p99": _pct(ts, 0.99),
            "step_ms_mean": statistics.mean(ts),
            "plan_ms_mean": statistics.mean(e["plan_ms"] for e in evs),
            "tokens_per_step": sum(e["tokens"] for e in evs) / n,
            "pages_churn_per_step": sum(
                e.get("pages_allocated", 0) + e.get("pages_evicted", 0)
                for e in evs) / n,
        })
    return rows


def run_obs(trace_path: str, art_dir: str = ART_DIR,
            policy: str | None = None) -> list[dict]:
    """Join trace-derived step timings with the roofline table."""
    from repro.obs.trace import validate_file
    errs = validate_file(trace_path)
    if errs:
        print(f"  roofline-obs: {trace_path} fails trace schema:")
        for e in errs[:5]:
            print(f"    {e}")
        return []
    with open(trace_path) as f:
        events = [json.loads(ln) for ln in f]
    rows = trace_summary(events)
    print("| kind | steps | step p50 (ms) | p90 | p99 | plan (ms) | "
          "tok/step | page churn/step |\n"
          "| --- | --- | --- | --- | --- | --- | --- | --- |")
    for r in rows:
        print(f"| {r['kind']} | {r['steps']} | {r['step_ms_p50']:.2f} | "
              f"{r['step_ms_p90']:.2f} | {r['step_ms_p99']:.2f} | "
              f"{r['plan_ms_mean']:.2f} | {r['tokens_per_step']:.1f} | "
              f"{r['pages_churn_per_step']:.1f} |")
    # join: modelled decode-step time (compute+memory+collective, which a
    # roofline treats as the slowest-term bound) vs measured decode p50
    decode = next((r for r in rows if r["kind"] == "decode"), None)
    art_rows = load_rows(art_dir)
    if policy:
        art_rows = [r for r in art_rows if r["policy"] == policy]
    joined = []
    if decode and art_rows:
        for a in art_rows:
            if not a["shape"].startswith("decode"):
                continue
            model_ms = max(a["compute_s"], a["memory_s"],
                           a["collective_s"]) * 1e3
            rec = {**{k: a[k] for k in ("arch", "shape", "mesh", "policy")},
                   "model_step_ms": model_ms,
                   "measured_step_ms_p50": decode["step_ms_p50"],
                   "measured_over_model":
                       decode["step_ms_p50"] / model_ms if model_ms else None}
            joined.append(rec)
            print(f"  roofline-obs,{a['arch']},{a['shape']},{a['policy']},"
                  f"model={model_ms:.3f}ms,"
                  f"measured_p50={decode['step_ms_p50']:.3f}ms,"
                  f"ratio={rec['measured_over_model']:.2f}")
    if not joined:
        print("  roofline-obs: no decode-shape artifacts to join "
              "(trace summary above stands alone)")
    return rows + joined


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--diff", nargs=2, metavar=("OLD_DIR", "NEW_DIR"),
                    help="diff two dry-run artifact dirs (before/after gate)")
    ap.add_argument("--obs", metavar="TRACE_JSONL",
                    help="join the table with step timings from a serving "
                         "trace (repro.obs.trace schema)")
    ap.add_argument("--policy", default=None,
                    help="restrict the --obs join to one policy's rows")
    args = ap.parse_args()
    if args.diff:
        run_diff(*args.diff)
    elif args.obs:
        run_obs(args.obs, policy=args.policy)
    else:
        run()


if __name__ == "__main__":
    main()
