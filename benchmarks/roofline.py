"""Aggregate dry-run artifacts into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints
the per-(arch x shape x policy) roofline terms + dominant bottleneck. This
is the source for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import argparse
import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

COLS = ["arch", "shape", "mesh", "policy", "compute_s", "memory_s",
        "collective_s", "dominant", "useful_flops_ratio"]


def load_rows(art_dir: str = ART_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def _variant(r: dict) -> str:
    notes = r.get("notes", "")
    tags = []
    if "zero1=True" in notes:
        tags.append("zero1")
    if "cache_dtype=int8" in notes:
        tags.append("int8")
    return "+".join(tags) or "-"


def fmt_row(r: dict) -> str:
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{_variant(r)} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} |")


def markdown_table(rows: list[dict]) -> str:
    head = ("| arch | shape | mesh | policy | variant | compute (s) | "
            "memory (s) | collective (s) | dominant | useful |\n"
            "| --- | --- | --- | --- | --- | --- | --- | --- | --- | --- |")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r["mesh"], r["policy"], _variant(r)))
    return "\n".join([head] + [fmt_row(r) for r in rows])


def run(quick: bool = False):
    rows = load_rows()
    if not rows:
        print("  roofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all)")
        return []
    print(markdown_table(rows))
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"  roofline,artifacts={len(rows)},dominants={doms}")
    return rows


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
