"""Paper hardware claims at TPU scale, derived from compiled dry-runs.

The paper's Fig. 3 mechanism is: a budget-capped cache means fewer HBM
bytes per decode step -> lower TPOT -> higher throughput. The CPU engine
benches (throughput.py) demonstrate the *functional* system but are
dispatch-bound at toy sizes; this module reproduces the claim at the
production scale the paper targets, from the dry-run artifacts:

  TPOT_roofline(policy)      = max(compute_s, memory_s, collective_s)
  throughput                 = global_batch / TPOT
  TPOT reduction (paper: 10-12% on A100 at budget 1024)
  throughput gain (paper: up to 37% over full cache at budget 1024; 3.1x
                   in the Fig. 4 long-generation regime)

Requires experiments/dryrun artifacts for decode_32k with policies
``full`` and ``paged_eviction`` (see launch/dryrun.py --policy).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
BATCH = {"decode_32k": 128, "long_500k": 1}


def _load(tag: str) -> dict | None:
    path = os.path.join(ART_DIR, tag + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def tpot_s(r: dict) -> float:
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def run(quick: bool = False):
    rows = []
    archs = sorted({os.path.basename(p).split("_decode_32k")[0]
                    for p in glob.glob(os.path.join(ART_DIR,
                                                    "*decode_32k*.json"))})
    for arch in archs:
        full = _load(f"{arch}_decode_32k_single_full")
        ev = _load(f"{arch}_decode_32k_single_paged_eviction")
        if not full or not ev:
            continue
        t_f, t_e = tpot_s(full), tpot_s(ev)
        thr_f = BATCH["decode_32k"] / t_f
        thr_e = BATCH["decode_32k"] / t_e
        rows.append((arch, t_f, t_e, thr_f, thr_e))
        print(f"  claim,{arch},tpot_full={t_f * 1e3:.2f}ms,"
              f"tpot_paged={t_e * 1e3:.2f}ms,"
              f"tpot_reduction={100 * (1 - t_e / t_f):.0f}%,"
              f"throughput_gain={thr_e / thr_f:.2f}x")
    if rows:
        gains = [e / f for (_, f, e, _, _) in rows]
        print(f"  claim,geomean_tpot_ratio,"
              f"{(float(__import__('numpy').prod(gains)) ** (1 / len(gains))):.3f}")
    return rows


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
