"""Shared benchmark helpers (CPU-scale reduced models; the paper's setup
scaled to this container — relative orderings are the reproduction target,
see EXPERIMENTS.md §Throughput)."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, CacheConfig, ModelConfig
from repro.models import init_model
from repro.obs import MetricsRegistry
from repro.serving import Engine, SamplingParams

_PARAM_CACHE: dict = {}


def reduced_model(arch: str, seed: int = 0):
    cfg = ARCHS[arch].reduced()
    key = (arch, seed)
    if key not in _PARAM_CACHE:
        _PARAM_CACHE[key] = init_model(jax.random.PRNGKey(seed), cfg)
    return cfg, _PARAM_CACHE[key]


@dataclass
class ServeResult:
    policy: str
    budget: int
    page: int
    throughput_tok_s: float      # decode tokens / decode wall time
    tpot_ms: float               # mean time per output token
    total_tokens: int
    pages_evicted: int
    steps: int
    pool_utilization: float = 0.0  # mapped / total physical pool pages
    # p50/p90/p99 (ms) from the engine metrics registry, measured AFTER the
    # warmup/compile window: {"itl_ms": {...}}
    percentiles: dict | None = None


def latency_percentiles(eng, names=("itl", "tpot")) -> dict:
    """Pull p50/p90/p99 (in ms) for the given engine latency histograms out
    of the metrics registry snapshot (DESIGN.md §9 benchmark consumption)."""
    snap = eng.metrics_snapshot()
    out = {}
    for name in names:
        h = snap.get(f"engine.{name}_s")
        if h and h.get("count"):
            out[f"{name}_ms"] = {q: h[q] * 1e3 if h[q] is not None else None
                                 for q in ("p50", "p90", "p99")}
    return out


def run_serving_bench(arch: str, *, policy: str, budget: int, page: int,
                      num_requests: int = 4, prompt_len: int = 64,
                      new_tokens: int = 48, max_batch: int = 4,
                      seed: int = 0, model=None) -> ServeResult:
    """Paper Fig.3 setup, scaled: synthetic prompts, concurrent batch,
    measure decode throughput + TPOT. ``model``: optional (cfg, params)
    override for custom size ladders."""
    cfg, params = model if model is not None else reduced_model(arch)
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=max_batch,
                 max_prompt_len=prompt_len, max_new_tokens=new_tokens,
                 sampling=SamplingParams(greedy=True), seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(num_requests):
        n = int(rng.integers(prompt_len // 2, prompt_len))
        eng.submit(rng.integers(0, cfg.vocab_size, size=n).astype(np.int32))
    # warm BOTH unified-step shapes (T == chunk while prompts prefill,
    # T == 1 decode-only) so compile time stays out of the measurement
    eng.step()
    while eng.scheduler.prefilling():
        eng.step()
    eng.step()
    eng.stats.decode_s = 0.0
    eng.stats.tokens_generated = 0
    eng.stats.decode_tokens = 0
    eng.stats.steps = 0
    eng.stats.decode_steps = 0
    # fresh registry so histogram percentiles exclude the compile window
    # (the engine reads self.obs.registry at every use site)
    eng.obs.registry = MetricsRegistry()
    eng.run()
    s = eng.stats
    tpot = (s.decode_s / max(s.decode_steps, 1)) * 1000.0
    return ServeResult(policy=policy, budget=budget, page=page,
                       throughput_tok_s=s.decode_tok_per_s, tpot_ms=tpot,
                       total_tokens=s.tokens_generated,
                       pages_evicted=s.pages_evicted, steps=s.steps,
                       pool_utilization=eng.pool_stats()["utilization"],
                       # itl only: the per-request tpot histogram averages
                       # over decode steps that span the compile window for
                       # requests admitted before warmup
                       percentiles=latency_percentiles(eng, names=("itl",)))


def merge_json(path, key, value) -> None:
    """Set ``key`` in the JSON object at ``path``, preserving other keys —
    latency.py and throughput.py both land sections in BENCH_latency.json
    and must not clobber each other."""
    import json
    import pathlib
    path = pathlib.Path(path)
    out = {}
    if path.exists():
        try:
            out = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            out = {}
    if not isinstance(out, dict):
        out = {}
    out[key] = value
    path.write_text(json.dumps(out, indent=2) + "\n")


def timeit_call(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall microseconds per call of a jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
