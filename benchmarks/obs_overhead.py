"""Telemetry overhead gate: instrumented vs bare decode TPOT (DESIGN.md §9).

The obs subsystem promises near-zero hot-path cost: the device stats
vector is pure jnp accumulation inside the already-jitted step (no host
callbacks), and the host side is one small device_get + a handful of dict
and histogram updates per ENGINE STEP (not per token). This benchmark
proves it: two engines over identical workloads — one fully instrumented
(metrics registry + JSONL trace), one with ``ObsConfig(metrics=False)``
(stats leaves are None, the cache pytree matches the pre-telemetry
engine) — measured in interleaved A/B pairs with alternating order so
machine drift cancels. The gate is the MEDIAN of per-pair TPOT ratios
(median-of-ratios is robust to a single noisy rep) and must stay at or
under ``GATE_RATIO``.

Writes BENCH_obs.json; ``main()`` exits non-zero when the gate fails, so
the CI step is the assertion, not a log line.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import statistics
import tempfile

import numpy as np

from benchmarks.common import reduced_model
from repro.configs import CacheConfig
from repro.obs import ObsConfig
from repro.serving import Engine, SamplingParams

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_obs.json"
GATE_RATIO = 1.02          # instrumented TPOT may cost at most 2%


def _make(cfg, params, obs, *, budget=32, page=8, max_batch=4,
          prompt_len=48, new_tokens=48, seed=0):
    ccfg = CacheConfig(page_size=page, cache_budget=budget,
                      policy="paged_eviction", dtype="float32")
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=max_batch,
                  max_prompt_len=prompt_len, max_new_tokens=new_tokens,
                  sampling=SamplingParams(greedy=True), seed=seed,
                  obs=obs)


def _one_rep(eng, prompts) -> float:
    """Run one workload on a warmed engine; return decode TPOT (ms) for
    just this rep (delta against the engine's running stats)."""
    s = eng.stats
    t0, n0 = s.decode_s, s.decode_steps
    for p in prompts:
        eng.submit(p.copy())
    eng.run()
    return (s.decode_s - t0) / max(s.decode_steps - n0, 1) * 1e3


def run(quick: bool = False, reps: int | None = None,
        new_tokens: int | None = None) -> dict:
    reps = reps if reps is not None else (5 if quick else 9)
    new_tokens = new_tokens if new_tokens is not None else \
        (24 if quick else 48)
    cfg, params = reduced_model("qwen2.5-3b")
    trace_path = os.path.join(tempfile.mkdtemp(prefix="obs_bench_"),
                              "trace.jsonl")
    on = _make(cfg, params, ObsConfig(trace_path=trace_path),
               new_tokens=new_tokens)
    off = _make(cfg, params, ObsConfig(metrics=False),
                new_tokens=new_tokens)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(24, 48))).astype(np.int32)
               for _ in range(4)]
    # warm both engines (compile both unified-step shapes) outside the
    # measurement window
    for eng in (on, off):
        _one_rep(eng, prompts)
    pairs = []
    for i in range(reps):
        # alternate order so slow drift hits both sides equally
        first, second = (on, off) if i % 2 == 0 else (off, on)
        a = _one_rep(first, prompts)
        b = _one_rep(second, prompts)
        t_on, t_off = (a, b) if first is on else (b, a)
        pairs.append({"rep": i, "tpot_on_ms": t_on, "tpot_off_ms": t_off,
                      "ratio": t_on / t_off})
    on.close()
    off.close()
    ratios = [p["ratio"] for p in pairs]
    med = statistics.median(ratios)
    out = {
        "setup": {"arch": "qwen2.5-3b (reduced)", "policy": "paged_eviction",
                  "reps": reps, "new_tokens": new_tokens,
                  "requests_per_rep": len(prompts),
                  "gate_ratio": GATE_RATIO},
        "pairs": pairs,
        "median_ratio": med,
        "overhead_pct": (med - 1.0) * 100.0,
        "trace_events": on.obs.writer.events_written,
        "gate_pass": med <= GATE_RATIO,
    }
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")
    verdict = "PASS" if out["gate_pass"] else "FAIL"
    print(f"  obs overhead: median tpot ratio {med:.4f} "
          f"({out['overhead_pct']:+.2f}%), gate {verdict} (<= {GATE_RATIO})")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    out = run(quick=args.quick, reps=args.reps)
    return 0 if out["gate_pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
