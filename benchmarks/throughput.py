"""Paper Figure 3 (a-c): throughput vs cache budget per eviction policy.

Paper setup (A100, vLLM, 1024-in/8192-out/64 concurrent) scaled to this
CPU container (reduced model, 64-in/48-out/4 concurrent). The reproduction
target is the RELATIVE ordering: PagedEviction ~ StreamingLLM > unstructured
(inverse_key_l2 / keydiff) and > Full Cache once the context exceeds the
budget (smaller cache = cheaper attention reads + rarer cache-table work).
"""
from __future__ import annotations

import argparse

from benchmarks.common import merge_json, run_serving_bench
from benchmarks.latency import BENCH_LATENCY_JSON

POLICIES = ["full", "paged_eviction", "streaming_llm", "inverse_key_l2",
            "keydiff"]


def run(arch: str = "llama-3.2-1b", budgets=(32, 64, 128), page: int = 8,
        new_tokens: int = 48, quick: bool = False):
    budgets = budgets[:1] if quick else budgets
    rows = []
    for budget in budgets:
        for pol in POLICIES:
            if pol == "full" and budget != budgets[0]:
                continue               # budget-independent
            r = run_serving_bench(arch, policy=pol, budget=budget, page=page,
                                  new_tokens=8 if quick else new_tokens)
            rows.append(r)
            print(f"  throughput,{arch},{pol},budget={budget},"
                  f"{r.throughput_tok_s:.1f} tok/s,tpot={r.tpot_ms:.1f}ms,"
                  f"pool_util={r.pool_utilization:.2f}")
    # merged (not clobbered) into the shared latency artifact: the decode
    # ITL/TPOT p50/p90/p99 per policy/budget from the metrics registry
    merge_json(BENCH_LATENCY_JSON, "throughput_percentiles",
               [{"arch": arch, "policy": r.policy, "budget": r.budget,
                 "throughput_tok_s": r.throughput_tok_s,
                 "percentiles": r.percentiles} for r in rows])
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-3.2-1b")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.arch, quick=args.quick)


if __name__ == "__main__":
    main()
