"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV lines (plus per-benchmark detail).
Quick mode (default) keeps CPU wall time tractable; --full runs the
paper-scaled sweeps used for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import time


def _section(name):
    print(f"== {name} ==", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full sweeps (slow); default is quick mode")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (accuracy, eviction_overhead, kernels, latency,
                            obs_overhead, page_size_ablation, paper_claims,
                            roofline, throughput)

    t0 = time.perf_counter()
    _section("throughput vs budget (paper Fig. 3a-c)")
    rows = throughput.run(quick=quick)
    for r in rows:
        print(f"throughput_{r.policy}_b{r.budget},"
              f"{1e6 / max(r.throughput_tok_s, 1e-9):.0f},"
              f"{r.throughput_tok_s:.1f} tok/s")

    _section("TPOT vs model size (paper Fig. 3d)")
    for tag, pol, r in latency.run(quick=quick):
        print(f"tpot_{tag}_{pol},{r.tpot_ms * 1000:.0f},{r.tpot_ms:.2f} ms")

    _section("TTFT/ITL under mixed load: chunked vs monolithic prefill")
    for mode, r in latency.run_prefill_modes().items():
        if mode == "setup":
            continue
        print(f"ttft_{mode},{r['long_ttft_ms'] * 1000:.0f},"
              f"{r['long_ttft_ms']:.1f} ms ttft / "
              f"{r['decoder_itl_max_ms']:.1f} ms itl_max")

    _section("eviction bookkeeping overhead (paper Limitation 4)")
    for pol, us, meta_us, _free in eviction_overhead.run(quick=quick):
        print(f"evict_overhead_{pol},{us:.0f},us/step "
              f"(metadata {meta_us:.0f} us)")

    _section("accuracy vs budget on long-context recall (paper Fig. 2 proxy)")
    full_acc, results = accuracy.run(quick=quick)
    print(f"accuracy_full_cache,0,{full_acc:.3f}")
    for (pol, budget), acc in results.items():
        print(f"accuracy_{pol}_b{budget},0,{acc:.3f}")

    _section("page-size ablation (paper Fig. 4)")
    page_size_ablation.run(quick=quick)

    _section("TPU-scale TPOT/throughput claims from dry-runs (paper Fig. 3)")
    paper_claims.run(quick=quick)

    _section("kernel perf pass: split-K / G-fold / fused epilogue (§8)")
    kres = kernels.run(quick=quick)
    for name, ok in kres["gates"].items():
        print(f"kernel_gate_{name},0,{'PASS' if ok else 'FAIL'}")

    _section("telemetry overhead gate: instrumented vs bare TPOT (§9)")
    ores = obs_overhead.run(quick=quick)
    print(f"obs_overhead_gate,{ores['overhead_pct'] * 100:.0f},"
          f"{'PASS' if ores['gate_pass'] else 'FAIL'} "
          f"(median ratio {ores['median_ratio']:.4f} <= "
          f"{obs_overhead.GATE_RATIO}; middle column = basis points)")
    if not ores["gate_pass"]:
        raise SystemExit("obs overhead gate FAILED: telemetry costs more "
                         f"than {(obs_overhead.GATE_RATIO - 1) * 100:.0f}% "
                         "TPOT — see BENCH_obs.json")

    _section("roofline terms from dry-run artifacts (assignment g)")
    roofline.run(quick=quick)

    print(f"total_bench_seconds,{(time.perf_counter() - t0) * 1e6:.0f},"
          f"{time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
