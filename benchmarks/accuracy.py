"""Paper Figure 2 proxy: accuracy vs cache budget per eviction policy.

LongBench cannot run offline, so the accuracy axis is a synthetic
long-context recall task (training/data.py): key-value pairs appear at the
START of the context followed by distractors; the query comes at the END.
A tiny dense model is trained (full attention, answer-slot loss) until it
solves the task, then evaluated with each eviction policy: the context is
prefilled under a budget (Alg.2 compression), the query is DECODED against
the evicted cache (so retained-token quality is what's measured), and the
answer argmax is scored.

Reproduction targets (qualitative, per the paper):
  - all policies -> full-cache accuracy as budget -> context length
  - StreamingLLM collapses once the budget excludes the early KV pairs
    (recency keeps distractors) — the paper's motivating failure mode
  - PagedEviction >= attention-free baselines at tight budgets

Beyond-paper ablation: the same sweep with an int8-quantized cache
(--int8) — the KV-quantization composition the paper cites as future work.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CacheConfig, ModelConfig
from repro.core import get_policy
from repro.models import decode_step, forward_prefill, init_model
from repro.training import (
    AdamWConfig,
    DataConfig,
    init_adamw,
    make_train_step,
    recall_batch,
)

POLICIES = ["paged_eviction", "streaming_llm", "inverse_key_l2", "keydiff"]

TINY = ModelConfig(
    name="tiny-recall", arch_type="dense", source="in-repo eval model",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=512, vocab_size=64, norm="rmsnorm", act="silu", dtype="float32",
)


def train_recall_model(seq_len: int = 32, steps: int = 900, batch: int = 32,
                       seed: int = 0, num_pairs: int = 2, key_space: int = 8):
    cfg = TINY
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      batch_size=batch, seed=seed, num_pairs=num_pairs,
                      key_space=key_space)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr_peak=3e-3, warmup_steps=50, total_steps=steps)))
    loss = float("nan")
    for i in range(steps):
        b = recall_batch(dcfg, i)             # mask: answer slot only
        batch_j = {k: jnp.asarray(v) for k, v in b.items() if k != "answers"}
        params, opt, m = step(params, opt, batch_j)
        loss = float(m["loss"])
    return cfg, params, dcfg, loss


def eval_policy(cfg, params, dcfg, policy: str, budget: int, page: int = 8,
                n_batches: int = 6, seed0: int = 10_000,
                cache_dtype: str = "float32") -> float:
    """Prefill the context under `policy`/`budget`; decode the 2-token query
    against the evicted cache; score the answer."""
    pol = get_policy(policy)
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype=cache_dtype)
    S = dcfg.seq_len
    correct = total = 0

    @jax.jit
    def run_case(tokens):
        ctx = tokens[:, :S - 2]
        lg, cache = forward_prefill(params, cfg, ctx, pol, ccfg,
                                    total_seq_hint=S + 2)
        lg, cache = decode_step(params, cfg, tokens[:, S - 2], cache, pol, ccfg)
        lg, cache = decode_step(params, cfg, tokens[:, S - 1], cache, pol, ccfg)
        return jnp.argmax(lg, axis=-1)

    for i in range(n_batches):
        b = recall_batch(dcfg, seed0 + i)
        pred = np.asarray(run_case(jnp.asarray(b["tokens"])))
        correct += int((pred == b["answers"]).sum())
        total += len(pred)
    return correct / total


def run(budgets=(8, 16, 24, 32), steps: int = 900, quick: bool = False,
        page: int = 8, int8: bool = False):
    if quick:
        steps, budgets = 500, (8, 16, 32)
    dt = "int8" if int8 else "float32"
    cfg, params, dcfg, loss = train_recall_model(steps=steps)
    print(f"  accuracy: trained tiny model, final loss {loss:.3f} "
          f"(cache dtype {dt})")
    nb = 3 if quick else 6
    results = {}
    full_acc = eval_policy(cfg, params, dcfg, "full", dcfg.seq_len,
                           page=page, n_batches=nb, cache_dtype=dt)
    print(f"  accuracy,full,budget=ctx,{full_acc:.3f}")
    results[("full", "ctx")] = full_acc
    for budget in budgets:
        for polname in POLICIES:
            acc = eval_policy(cfg, params, dcfg, polname, budget, page=page,
                              n_batches=nb, cache_dtype=dt)
            results[(polname, budget)] = acc
            print(f"  accuracy,{polname},budget={budget},{acc:.3f}")
    return full_acc, results


def run_regret(policies=POLICIES, budget: int = 32, quick: bool = False,
               out: str | None = None) -> dict:
    """Eviction-regret companion to the accuracy sweep: for each policy run
    the shadow-probe harness (repro.obs.regret) on a small serving workload
    and report mean output divergence + attention mass lost to eviction.
    Unlike the recall accuracy above (task-level, end-of-context query) this
    measures the *mechanistic* damage each policy does to every probed
    decode step — the two should rank policies consistently."""
    from repro.obs.regret import regret_smoke
    pols = list(policies) + ["full"]
    if quick:
        pols = [policies[0], "full"]
    results = {}
    for polname in pols:
        b = budget if polname != "full" else 1024
        s = regret_smoke(polname, budget=b)
        s.pop("outputs", None)
        results[polname] = s
        print(f"  regret,{polname},budget={b},probes={s['probes']},"
              f"divergence={s['mean_divergence']:.4g},"
              f"evicted_mass={s['mean_evicted_mass']:.4g},"
              f"shadow_mb={s['shadow_mb']}")
    if out:
        from benchmarks.common import merge_json
        merge_json(out, "regret", results)
        print(f"  merged 'regret' section into {out}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=900)
    ap.add_argument("--int8", action="store_true",
                    help="quantized-cache ablation (beyond-paper)")
    ap.add_argument("--regret", action="store_true",
                    help="run the eviction-regret shadow-probe sweep "
                         "instead of the recall accuracy sweep")
    ap.add_argument("--out", default=None, metavar="BENCH_JSON",
                    help="with --regret: merge the per-policy regret "
                         "summaries into this BENCH artifact (merge-not-"
                         "clobber, benchmarks/common.merge_json)")
    args = ap.parse_args()
    if args.regret:
        run_regret(quick=args.quick, out=args.out)
    else:
        run(steps=args.steps, quick=args.quick, int8=args.int8)


if __name__ == "__main__":
    main()
