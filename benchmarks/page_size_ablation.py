"""Paper Figure 4 / §5.5: page-size ablation — throughput and accuracy
across page sizes for each compression method. The paper finds PagedEviction
keeps its throughput/accuracy balance across page sizes (16/32 best for
vLLM); here we sweep the reduced-scale equivalents."""
from __future__ import annotations

import argparse

from benchmarks.accuracy import eval_policy, train_recall_model
from benchmarks.common import run_serving_bench

PAGES = (4, 8, 16)
POLICIES = ["paged_eviction", "streaming_llm", "inverse_key_l2"]


def run(arch: str = "llama-3.2-1b", budget: int = 64, quick: bool = False):
    pages = PAGES[:2] if quick else PAGES
    rows = []
    for page in pages:
        for pol in POLICIES:
            r = run_serving_bench(arch, policy=pol, budget=budget, page=page,
                                  new_tokens=8 if quick else 32)
            rows.append(r)
            print(f"  pagesize,{arch},{pol},page={page},"
                  f"{r.throughput_tok_s:.1f} tok/s")
    # accuracy side (quick: skip re-training by keeping steps small)
    cfg, params, dcfg, _ = train_recall_model(steps=120 if quick else 300)
    for page in pages:
        for pol in POLICIES:
            acc = eval_policy(cfg, params, dcfg, pol, budget, page=page,
                              n_batches=2 if quick else 6)
            print(f"  pagesize_acc,{pol},page={page},{acc:.3f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
