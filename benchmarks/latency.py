"""Paper Figure 3 (d): time-per-output-token across model sizes at a fixed
budget — PagedEviction vs Full Cache (paper: 10-12% TPOT reduction) vs
StreamingLLM (paper: comparable).

The paper's Llama 1B/3B/8B ladder is reproduced as a d_model ladder of
reduced models (layer-count reductions collapse the ladder on CPU)."""
from __future__ import annotations

import argparse
from dataclasses import replace

import jax

from benchmarks.common import run_serving_bench
from repro.configs import PAPER_ARCHS
from repro.models import init_model

SIZES = {"1b": ("llama-3.2-1b", 128), "3b": ("llama-3.2-3b", 192),
         "8b": ("llama-3.1-8b", 256)}


def run(budget: int = 64, page: int = 8, quick: bool = False):
    rows = []
    for tag, (arch, dm) in SIZES.items():
        cfg = replace(PAPER_ARCHS[arch].reduced(), d_model=dm, num_heads=4,
                      num_kv_heads=2, head_dim=dm // 4)
        params = init_model(jax.random.PRNGKey(0), cfg)
        pols = ["full", "paged_eviction"] if quick else \
            ["full", "paged_eviction", "streaming_llm"]
        for pol in pols:
            r = run_serving_bench(arch, policy=pol, budget=budget, page=page,
                                  new_tokens=8 if quick else 32,
                                  model=(cfg, params))
            rows.append((tag, pol, r))
            print(f"  tpot,{tag},{pol},{r.tpot_ms:.2f} ms/token")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
