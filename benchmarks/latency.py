"""Paper Figure 3 (d): time-per-output-token across model sizes at a fixed
budget — PagedEviction vs Full Cache (paper: 10-12% TPOT reduction) vs
StreamingLLM (paper: comparable).

The paper's Llama 1B/3B/8B ladder is reproduced as a d_model ladder of
reduced models (layer-count reductions collapse the ladder on CPU).

Also: TTFT / inter-token-latency under MIXED prefill+decode load, chunked
vs monolithic prefill (monolithic == whole prompt as one chunk). Chunked
prefill interleaves decode tokens with a long prompt's chunks, so the
decode slots' ITL tail shrinks while the long prompt's TTFT pays a small
per-chunk overhead — results recorded in BENCH_prefill.json."""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import merge_json, reduced_model, run_serving_bench
from repro.configs import PAPER_ARCHS, CacheConfig
from repro.models import init_model
from repro.serving import Engine, SamplingParams

SIZES = {"1b": ("llama-3.2-1b", 128), "3b": ("llama-3.2-3b", 192),
         "8b": ("llama-3.1-8b", 256)}

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_prefill.json"
BENCH_LATENCY_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_latency.json"


def run_mixed_latency(chunk_size: int, *, prompt_len: int = 64,
                      short_len: int = 8, new_tokens: int = 24,
                      max_batch: int = 4, budget: int = 32, page: int = 8,
                      seed: int = 0) -> dict:
    """Mixed load: (max_batch - 1) short decoders + 1 long prompt arriving
    after they are running. Returns TTFT of the long request, decoder ITL
    (mean + p max) during its prefill, and decode stall — all in ms."""
    cfg, params = reduced_model("qwen2.5-3b")
    ccfg = CacheConfig(page_size=page, cache_budget=budget,
                       policy="paged_eviction", dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=max_batch,
                 max_prompt_len=prompt_len, max_new_tokens=new_tokens,
                 sampling=SamplingParams(greedy=True), seed=seed,
                 chunk_size=chunk_size)
    rng = np.random.default_rng(seed)
    short = [eng.submit(rng.integers(0, cfg.vocab_size, size=short_len)
                        .astype(np.int32)) for _ in range(max_batch - 1)]
    # warm both program shapes + bring the short requests to RUNNING
    for _ in range(4):
        eng.step()
    long_req = eng.submit(
        rng.integers(0, cfg.vocab_size, size=prompt_len).astype(np.int32))
    step_times = []
    while not long_req.num_generated:
        t0 = time.perf_counter()
        eng.step()
        step_times.append(time.perf_counter() - t0)
    itl = [dt for r in short for dt in r.decode_times[-len(step_times):]]
    eng.run()
    return {
        "chunk_size": chunk_size,
        "long_ttft_ms": long_req.ttft * 1e3,
        "prefill_steps": len(step_times),
        # decoder ITL during the long prefill: chunked bounds every step at
        # ~chunk tokens of work, monolithic makes decoders wait out one
        # whole-prompt step (the ITL-max spike the unified loop removes)
        "decoder_itl_mean_ms": statistics.mean(itl) * 1e3 if itl else None,
        "decoder_itl_max_ms": max(itl) * 1e3 if itl else None,
        "decode_tokens_during_prefill":
            sum(min(len(r.decode_times), len(step_times)) for r in short),
    }


def run_shared_prefix(*, n_requests: int = 3, prefix_len: int = 40,
                      tail_len: int = 16, new_tokens: int = 8,
                      budget: int = 64, page: int = 8, seed: int = 0) -> dict:
    """Shared-prefix mixed load (DESIGN.md §7): ``n_requests`` prompts with a
    common ``prefix_len``-token head, run with CoW prefix sharing on vs off.
    Sharing lets every request after the first adopt the resident prefix
    pages, skipping those prompt chunks entirely — fewer prefill steps,
    lower follower TTFT, and fewer physical pool pages in flight."""
    cfg, params = reduced_model("qwen2.5-3b")
    prompt_len = prefix_len + tail_len
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len)
    prompts = [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, size=tail_len)])
        .astype(np.int32) for _ in range(n_requests)]

    def one(sharing: bool) -> dict:
        ccfg = CacheConfig(page_size=page, cache_budget=budget,
                           policy="paged_eviction", dtype="float32")
        eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=n_requests + 1,
                     max_prompt_len=prompt_len + page,
                     max_new_tokens=new_tokens,
                     sampling=SamplingParams(greedy=True), seed=seed,
                     chunk_size=16, prefix_sharing=sharing)
        for p in prompts:
            eng.submit(p)
        peak = 0
        while eng.step():
            ps = eng.pool_stats()
            peak = max(peak, ps["pool_pages"] - ps["free_pages"])
        done = eng.scheduler.finished
        ttfts = sorted(r.ttft * 1e3 for r in done if r.ttft > 0)
        return {
            "prefix_sharing": sharing,
            "steps": eng.stats.steps,
            "shared_prefix_hits": eng.stats.shared_prefix_hits,
            "prompt_tokens_skipped": eng.stats.shared_prefix_tokens,
            "peak_pool_pages": peak,
            # followers adopt the prefix, so the TTFT tail is where the
            # sharing win shows up (the first request always prefills fully)
            "ttft_ms_first": ttfts[0] if ttfts else None,
            "ttft_ms_max": ttfts[-1] if ttfts else None,
        }

    return {
        "setup": {"arch": "qwen2.5-3b (reduced)", "n_requests": n_requests,
                  "prefix_len": prefix_len, "tail_len": tail_len,
                  "policy": "paged_eviction", "budget": budget, "page": page},
        "sharing": one(True),
        "no_sharing": one(False),
    }


def run_prefill_modes(prompt_len: int = 64) -> dict:
    """Chunked (16-token chunks) vs monolithic (whole-prompt chunk) under
    the same mixed load, plus the shared-prefix scenario; writes
    BENCH_prefill.json."""
    out = {
        "setup": {"arch": "qwen2.5-3b (reduced)", "prompt_len": prompt_len,
                  "short_decoders": 3, "policy": "paged_eviction",
                  "budget": 32, "page": 8},
        "chunked": run_mixed_latency(16, prompt_len=prompt_len),
        "monolithic": run_mixed_latency(prompt_len, prompt_len=prompt_len),
        "shared_prefix": run_shared_prefix(),
    }
    BENCH_JSON.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")
    for mode in ("chunked", "monolithic"):
        r = out[mode]
        print(f"  {mode:>10}: ttft={r['long_ttft_ms']:.1f}ms "
              f"itl_max={r['decoder_itl_max_ms']:.1f}ms "
              f"decode_during_prefill={r['decode_tokens_during_prefill']}")
    for mode in ("sharing", "no_sharing"):
        r = out["shared_prefix"][mode]
        print(f"  {mode:>10}: steps={r['steps']} "
              f"skipped={r['prompt_tokens_skipped']} "
              f"peak_pages={r['peak_pool_pages']} "
              f"ttft_max={r['ttft_ms_max']:.1f}ms")
    return out


def run(budget: int = 64, page: int = 8, quick: bool = False):
    rows = []
    for tag, (arch, dm) in SIZES.items():
        cfg = replace(PAPER_ARCHS[arch].reduced(), d_model=dm, num_heads=4,
                      num_kv_heads=2, head_dim=dm // 4)
        params = init_model(jax.random.PRNGKey(0), cfg)
        pols = ["full", "paged_eviction"] if quick else \
            ["full", "paged_eviction", "streaming_llm"]
        for pol in pols:
            r = run_serving_bench(arch, policy=pol, budget=budget, page=page,
                                  new_tokens=8 if quick else 32,
                                  model=(cfg, params))
            rows.append((tag, pol, r))
            pct = (r.percentiles or {}).get("itl_ms") or {}
            print(f"  tpot,{tag},{pol},{r.tpot_ms:.2f} ms/token"
                  + (f" itl p50={pct['p50']:.2f} p99={pct['p99']:.2f}"
                     if pct.get("p50") is not None else ""))
    # latency results land in a committed artifact on EVERY run — the TPOT
    # ladder used to live only in stdout and silently went stale. The
    # p50/p90/p99 columns come from the engine metrics registry (post-warmup
    # window), so the summary carries tail latency, not just means.
    merge_json(BENCH_LATENCY_JSON, "setup",
               {"budget": budget, "page": page, "quick": quick,
                "sizes": {t: a for t, (a, _) in SIZES.items()}})
    merge_json(BENCH_LATENCY_JSON, "tpot_ms",
               [{"size": tag, "policy": pol, "tpot_ms": r.tpot_ms,
                 "throughput_tok_s": r.throughput_tok_s,
                 "pool_utilization": r.pool_utilization,
                 "percentiles": r.percentiles}
                for tag, pol, r in rows])
    print(f"wrote {BENCH_LATENCY_JSON}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-mixed", action="store_true",
                    help="skip the chunked-vs-monolithic TTFT/ITL bench")
    args = ap.parse_args()
    run(quick=args.quick)
    if not args.skip_mixed:
        run_prefill_modes()


if __name__ == "__main__":
    main()
