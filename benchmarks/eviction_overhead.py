"""Paper Limitation 4 microbenchmark: per-step eviction bookkeeping cost.

Times ONLY the cache-maintenance path (write + policy post_write) per
policy at steady state, isolating the paper's overhead argument from model
compute: PagedEviction pays page-scoring once per page_size steps;
token-per-step baselines pay argmin-over-cache every step; keydiff
additionally re-reads all cached keys every step. With the shared page
pool this path now includes the free-list allocator (rollover pops a page,
eviction pushes one back); steady-state free-pool headroom is reported
alongside the timing.

The eviction-METADATA term is reported as its own column: the cost of
producing the importance statistics the policy ranks by (the stored-score
page reduction for PagedEviction, the per-token score gather for the
unstructured baselines, the full key re-read for keydiff). This is exactly
the term the fused attention epilogue removes from the hot path
(DESIGN.md §8): when the Pallas kernels run with ``return_scores``, page
scores fall out of the attention pass and the metadata column goes to ~0.
``benchmarks/kernels.py`` lands these rows in BENCH_kernels.json next to
the fused-epilogue measurement."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_call
from repro.configs import CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache

POLICIES = ["full", "paged_eviction", "streaming_llm", "inverse_key_l2",
            "keydiff"]


def _metadata_fn(pol, ccfg):
    """The policy's metadata source, jitted in isolation. Returns None for
    policies with no score computation (full: nothing is ranked)."""
    if pol.name == "full":
        return None
    if pol.structured and pol.name == "paged_eviction":
        # stored-score page reduction — what the fused epilogue replaces
        return jax.jit(lambda c: c.page_scores())
    # token policies rank per-token eviction scores every step
    return jax.jit(lambda c: pol._evict_scores(c, ccfg))


def run(B: int = 8, KV: int = 2, hd: int = 64, page: int = 16,
        budget: int = 256, quick: bool = False):
    """Returns rows (policy, step_us, metadata_us, pool_free)."""
    steps_to_fill = budget + 2 * page
    rows = []
    for polname in POLICIES:
        pol = get_policy(polname)
        ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=polname,
                           dtype="float32")
        pages = pol.slab_pages(ccfg, steps_to_fill + page)
        cache = init_layer_cache(B, pages, page, KV, hd, jnp.float32)

        @jax.jit
        def step(cache, k, v, t):
            return decode_append(cache, k, v, t, pol, ccfg).cache

        rng = jax.random.PRNGKey(0)
        # drive to steady state (budget full)
        for t in range(steps_to_fill):
            rng, k1, k2 = jax.random.split(rng, 3)
            cache = step(cache, jax.random.normal(k1, (B, KV, hd)),
                         jax.random.normal(k2, (B, KV, hd)),
                         jnp.full((B,), t, jnp.int32))
        k = jax.random.normal(rng, (B, KV, hd))
        t = jnp.full((B,), steps_to_fill, jnp.int32)
        iters = 10 if quick else 30
        us = timeit_call(step, cache, k, k, t, iters=iters)
        meta_fn = _metadata_fn(pol, ccfg)
        meta_us = (timeit_call(meta_fn, cache, iters=iters)
                   if meta_fn is not None else 0.0)
        free = int(cache.num_free())
        rows.append((polname, us, meta_us, free))
        print(f"  evict_overhead,{polname},{us:.0f} us/step,"
              f"metadata={meta_us:.0f} us,"
              f"pool_free={free}/{cache.pool_pages}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
