"""Kernel perf pass gate (DESIGN.md §8) -> BENCH_kernels.json.

Covers the three PR optimisations with before/after roofline rows:

  1. split-K flash-decode — decode latency model at contexts {256, 1k, 4k}
     for split factors {1, 2, 4, 8} on the same pool, plus interpret-mode
     parity wall-clock. The primary latency figures are the ROOFLINE MODEL
     (serial grid-chain x per-step latency + combine), the same analytic
     practice as paper_claims.py: interpret mode executes every grid step
     in Python sequentially, so it cannot exhibit the split-axis
     parallelism (megacore `dimension_semantics`, or the model-axis pool
     shard; sharding/rules.py carries the partial specs). Measured
     interpret numbers ride alongside, clearly labeled.
  2. G-fold prefill fetch — HBM bytes moved per chunk-prefill call from
     EXACT BlockSpec accounting (count the tile DMAs each grid executes),
     per-Q-head vs G-fold, on the mixtral / gemma3 GQA head geometries;
     the roofline memory term drops ~Gx.
  3. fused eviction-score epilogue — metadata bytes/latency of the
     standalone block_score pool pass vs the epilogue's marginal outputs
     (two (B, KV, P, page) f32 norm tiles the kernel writes from data
     already in VMEM), plus measured interpret wall-clock of both paths.

Model constants come from repro.launch.mesh (v5p-class chip); the
per-step latency term is the sequential-grid step cost (DMA issue +
(G, hd) x (page, hd) tile on the VPU — latency-bound at decode shapes,
not bandwidth-bound).
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit_call
from repro.launch.mesh import HBM_BW

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_kernels.json"

PAGE = 16
CONTEXTS = [256, 1024, 4096]
SPLITS = [1, 2, 4, 8]
# sequential-grid per-step latency (s): DMA issue + one decode tile on the
# VPU. Decode steps move ~8-16 KB (tens of ns at HBM_BW) — the fixed
# per-step cost dominates, which is exactly why the serial page walk is
# the long-context bottleneck the split shortens.
STEP_LAT_S = 1e-6
# split combine: one (S, G, hd) f32 renormalisation on already-resident
# partials — a handful of VPU ops + max/sum reduces
COMBINE_LAT_S = 2e-6

F32 = 4


# ---------------------------------------------------------------------------
# 1. split-K decode latency
# ---------------------------------------------------------------------------

def decode_latency_model_us(P: int, splits: int, *, kv: int, g: int, hd: int,
                            page: int = PAGE, itemsize: int = F32) -> float:
    """Roofline latency of one decode call: the splits execute in parallel
    (split axis is grid-parallel / sharded), each walking ceil(P/S) pages
    sequentially; S > 1 pays one combine."""
    pps = -(-P // splits)
    tile_bytes = 2 * page * hd * itemsize            # K + V page per step
    step_s = STEP_LAT_S + tile_bytes / HBM_BW
    combine_s = COMBINE_LAT_S if splits > 1 else 0.0
    # per-(b, kv-head) chain; heads are grid-parallel in the model
    del kv, g
    return (pps * step_s + combine_s) * 1e6


def _synthetic_pool(key, B, KV, hd, P, page, steps=None):
    """Fully-mapped random pool + block tables (no eviction churn — parity
    on churned pools is tests/test_kernel_perf.py's job; the bench only
    needs representative shapes)."""
    N = B * P + 2
    ks = jax.random.split(key, 4)
    kp = jax.random.normal(ks[0], (KV, N, page, hd), jnp.float32)
    vp = jax.random.normal(ks[1], (KV, N, page, hd), jnp.float32)
    bt = jax.random.permutation(ks[2], N)[:B * P].reshape(B, P).astype(jnp.int32)
    pos = np.full((N, page), -1, np.int32)
    btn = np.asarray(bt)
    for b in range(B):
        for p in range(P):
            pos[btn[b, p]] = np.arange(p * page, (p + 1) * page)
    return kp, vp, jnp.asarray(pos), bt


def bench_split_k(quick: bool = True) -> dict:
    B, KV, G, hd = 1, 1, 4, 64
    iters = 3 if quick else 10
    out = {"page_size": PAGE, "B": B, "KV": KV, "G": G, "hd": hd,
           "model": {"step_lat_s": STEP_LAT_S, "combine_lat_s": COMBINE_LAT_S,
                     "hbm_bw": HBM_BW},
           "contexts": {}}
    from repro.kernels.paged_attention import paged_attention_kernel
    for ctx in CONTEXTS:
        P = ctx // PAGE
        kp, vp, pos, bt = _synthetic_pool(jax.random.PRNGKey(ctx), B, KV, hd,
                                          P, PAGE)
        q = jax.random.normal(jax.random.PRNGKey(1), (B, KV, G, hd))
        cur = jnp.full((B,), ctx - 1, jnp.int32)
        row = {}
        base = None
        for s in SPLITS:
            call = jax.jit(lambda q, kp, vp, pos, bt, cur, s=s:
                           paged_attention_kernel(q, kp, vp, pos, bt, cur,
                                                  num_splits=s))
            meas = timeit_call(call, q, kp, vp, pos, bt, cur,
                               iters=iters, warmup=1)
            model = decode_latency_model_us(P, s, kv=KV, g=G, hd=hd)
            if s == 1:
                base = (model, meas)
            row[str(s)] = {"model_latency_us": model,
                           "measured_interpret_us": meas,
                           "model_speedup_vs_split1": base[0] / model}
        out["contexts"][str(ctx)] = row
        print(f"  splitk,ctx={ctx},split8_model_speedup="
              f"{row['8']['model_speedup_vs_split1']:.2f}x")
    return out


# ---------------------------------------------------------------------------
# 2. G-fold prefill HBM bytes
# ---------------------------------------------------------------------------

def prefill_hbm_bytes(B: int, KV: int, G: int, T: int, P: int, *, hd: int,
                      page: int = PAGE, itemsize: int = F32,
                      per_qhead: bool = False) -> int:
    """Exact tile-DMA accounting of one paged chunk-prefill call from the
    kernel's BlockSpecs. per-Q-head grid (B, H, P) re-fetches each K/V page
    per Q head; the G-fold grid (B, KV, P) fetches it once per KV-head
    group. Q/O tiles revisit the same block across the page walk, so Pallas
    fetches/writes them once per (b, head-group)."""
    H = KV * G
    kv_steps = (B * H * P) if per_qhead else (B * KV * P)
    kv_bytes = kv_steps * 2 * page * hd * itemsize       # K + V tiles
    pos_bytes = kv_steps * page * 4                      # kpos tile per step
    q_rows = T if per_qhead else G * T
    groups = (B * H) if per_qhead else (B * KV)
    q_bytes = groups * q_rows * hd * itemsize            # q fetched once
    o_bytes = groups * q_rows * hd * itemsize            # o written once
    qpos_bytes = groups * q_rows * 4
    return kv_bytes + pos_bytes + q_bytes + o_bytes + qpos_bytes


def bench_gfold(quick: bool = True) -> dict:
    from repro.kernels.flash_prefill import (
        paged_flash_prefill_kernel,
        paged_flash_prefill_kernel_per_qhead,
    )
    # production head geometries (bytes model) + reduced interpret run
    GEOMS = {"mixtral-8x7b": dict(KV=8, G=4, hd=128),
             "gemma3-27b": dict(KV=16, G=2, hd=128)}
    T, P, Bm = 128, 256, 8                                # model shape (4k ctx)
    out = {"model_shape": {"B": Bm, "T": T, "P": P, "page": PAGE}, "geoms": {}}
    for name, gm in GEOMS.items():
        before = prefill_hbm_bytes(Bm, gm["KV"], gm["G"], T, P, hd=gm["hd"],
                                   per_qhead=True)
        after = prefill_hbm_bytes(Bm, gm["KV"], gm["G"], T, P, hd=gm["hd"],
                                  per_qhead=False)
        out["geoms"][name] = {
            **gm,
            "hbm_bytes_per_qhead": before,
            "hbm_bytes_gfold": after,
            "bytes_ratio": before / after,
            "memory_s_per_qhead": before / HBM_BW,
            "memory_s_gfold": after / HBM_BW,
        }
        print(f"  gfold,{name},G={gm['G']},bytes_ratio="
              f"{before / after:.2f}x")
    # interpret-mode wall clock + bit parity at reduced scale
    B, KV, G, hd, Tr, Pr = 1, 2, 4, 64, 16, 16
    kp, vp, pos, bt = _synthetic_pool(jax.random.PRNGKey(0), B, KV, hd,
                                      Pr, PAGE)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, Tr, KV * G, hd))
    qpos = jnp.broadcast_to(
        jnp.arange(Pr * PAGE - Tr, Pr * PAGE, dtype=jnp.int32), (B, Tr))
    iters = 3 if quick else 10
    old = jax.jit(lambda *a: paged_flash_prefill_kernel_per_qhead(*a))
    new = jax.jit(lambda *a: paged_flash_prefill_kernel(*a))
    us_old = timeit_call(old, q, kp, vp, pos, bt, qpos, iters=iters, warmup=1)
    us_new = timeit_call(new, q, kp, vp, pos, bt, qpos, iters=iters, warmup=1)
    bitpar = bool(jnp.all(old(q, kp, vp, pos, bt, qpos) ==
                          new(q, kp, vp, pos, bt, qpos)))
    out["interpret"] = {"per_qhead_us": us_old, "gfold_us": us_new,
                        "bit_parity": bitpar}
    print(f"  gfold,interpret,{us_old:.0f}us -> {us_new:.0f}us,"
          f"bit_parity={bitpar}")
    return out


# ---------------------------------------------------------------------------
# 3. fused eviction-score epilogue
# ---------------------------------------------------------------------------

def bench_fused_epilogue(quick: bool = True) -> dict:
    from repro.kernels.block_score import block_score_kernel
    from repro.kernels.paged_attention import paged_attention_kernel
    B, KV, G, hd, P = 2, 2, 2, 64, 16
    N = B * P + 2
    kp, vp, pos, bt = _synthetic_pool(jax.random.PRNGKey(7), B, KV, hd,
                                      P, PAGE)
    q = jax.random.normal(jax.random.PRNGKey(2), (B, KV, G, hd))
    cur = jnp.full((B,), P * PAGE - 1, jnp.int32)
    iters = 3 if quick else 10

    # bytes model: the standalone pass re-reads the whole pool; the fused
    # epilogue only WRITES the two norm tiles (K/V already in VMEM for
    # attention — zero extra reads)
    standalone_bytes = N * PAGE * KV * hd * 2 * F32 + N * PAGE * 4
    fused_extra_bytes = 2 * B * KV * P * PAGE * F32
    ratio = fused_extra_bytes / standalone_bytes

    # pool layout for block_score is (N, page, KV, hd)
    kp_n = jnp.moveaxis(kp, 0, 2)
    vp_n = jnp.moveaxis(vp, 0, 2)
    standalone = jax.jit(lambda k, v, p: block_score_kernel(k, v, p))
    us_standalone = timeit_call(standalone, kp_n, vp_n, pos,
                                iters=iters, warmup=1)
    plain = jax.jit(lambda *a: paged_attention_kernel(*a))
    fused = jax.jit(lambda *a: paged_attention_kernel(*a, return_scores=True))
    us_plain = timeit_call(plain, q, kp, vp, pos, bt, cur,
                           iters=iters, warmup=1)
    us_fused = timeit_call(fused, q, kp, vp, pos, bt, cur,
                           iters=iters, warmup=1)
    out = {
        "shape": {"B": B, "KV": KV, "hd": hd, "P": P, "page": PAGE,
                  "pool_pages": N},
        "standalone_hbm_bytes": standalone_bytes,
        "fused_extra_hbm_bytes": fused_extra_bytes,
        "model_overhead_ratio": ratio,
        "interpret": {
            "standalone_block_score_us": us_standalone,
            "decode_us": us_plain,
            "decode_with_scores_us": us_fused,
            "marginal_us": max(us_fused - us_plain, 0.0),
        },
    }
    print(f"  fused_epilogue,model_overhead={100 * ratio:.1f}% of "
          f"standalone pass,interpret_marginal="
          f"{out['interpret']['marginal_us']:.0f}us")
    return out


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def run(quick: bool = True) -> dict:
    print("  [split-K decode]")
    splitk = bench_split_k(quick)
    print("  [G-fold prefill]")
    gfold = bench_gfold(quick)
    print("  [fused score epilogue]")
    fused = bench_fused_epilogue(quick)
    print("  [eviction metadata (Limitation 4) with fused scores]")
    from benchmarks import eviction_overhead
    meta_rows = [
        {"policy": p, "step_us": us, "metadata_us": mus, "pool_free": free}
        for (p, us, mus, free) in eviction_overhead.run(quick=quick)
    ]

    ctx4k = splitk["contexts"]["4096"]
    mx = gfold["geoms"]["mixtral-8x7b"]
    roofline_rows = [
        {"name": "split_k_decode_4k",
         "unit": "us (model latency)",
         "before": ctx4k["1"]["model_latency_us"],
         "after": ctx4k["8"]["model_latency_us"],
         "improvement": ctx4k["8"]["model_speedup_vs_split1"]},
        {"name": "gfold_prefill_mixtral_memory_term",
         "unit": "s (roofline memory term)",
         "before": mx["memory_s_per_qhead"],
         "after": mx["memory_s_gfold"],
         "improvement": mx["bytes_ratio"]},
        {"name": "fused_epilogue_metadata_bytes",
         "unit": "bytes per score refresh",
         "before": fused["standalone_hbm_bytes"],
         "after": fused["fused_extra_hbm_bytes"],
         "improvement": fused["standalone_hbm_bytes"] /
         max(fused["fused_extra_hbm_bytes"], 1)},
    ]
    result = {
        "split_k_decode": splitk,
        "gfold_prefill": gfold,
        "fused_epilogue": fused,
        "eviction_metadata": meta_rows,
        "roofline_rows": roofline_rows,
        "gates": {
            "splitk_4k_speedup_ge_1p5": ctx4k["8"]["model_speedup_vs_split1"]
            >= 1.5,
            "gfold_bytes_ratio_near_G": all(
                g["bytes_ratio"] > 0.7 * g["G"]
                for g in gfold["geoms"].values()),
            "fused_overhead_le_10pct": fused["model_overhead_ratio"] <= 0.10,
        },
    }
    BENCH_JSON.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {BENCH_JSON}")
    for k, v in result["gates"].items():
        print(f"  gate,{k},{'PASS' if v else 'FAIL'}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)


if __name__ == "__main__":
    main()
