import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

"""TP scaling benchmark + memory gate (BENCH_tp.json).

For tp in {1, 2, 4} serve the same churned shared-prefix workload on the
SAME reduced(tp=4) config (gemma3 GQA + mixtral MoE) and record, per degree:

  - per-device pool payload bytes (``Engine.pool_bytes()``) — the point of
    TP serving: the pool splits over the KV-head axis, so per-device bytes
    must fall ~1/tp,
  - decode step latency (mean ms/step; CPU-mesh numbers are for trend
    lines, not absolutes),
  - modelled collective bytes per step from the compiled HLO of the decode
    program (ring all-reduce model, ``launch.analysis.parse_collectives``),
  - greedy-token parity vs tp=1.

Exit code IS the gate (CI mesh tier):
  1. parity: every tp degree reproduces the tp=1 tokens exactly;
  2. memory: per_device_max <= payload_total/tp + one page of slack.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m benchmarks.tp_scaling --quick
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CacheConfig, get_arch
from repro.launch.analysis import parse_collectives
from repro.models.transformer import init_model
from repro.obs import ObsConfig
from repro.serving import Engine, SamplingParams

ARCHS = ("gemma3-27b", "mixtral-8x7b")
TP_DEGREES = (1, 2, 4)


def _build(arch, params, tp, *, budget, page, new_tokens):
    cfg = get_arch(arch).reduced(tp=4)
    ccfg = CacheConfig(page_size=page, cache_budget=budget,
                       policy="paged_eviction", dtype="float32")
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=3,
                  max_prompt_len=48, max_new_tokens=new_tokens,
                  sampling=SamplingParams(greedy=True), chunk_size=16,
                  seed=0, tp=tp, obs=ObsConfig())


def _workload(eng, n_reqs):
    rng = np.random.default_rng(0)
    shared = rng.integers(0, eng.cfg.vocab_size, size=16)
    for i in range(n_reqs):
        tail = rng.integers(0, eng.cfg.vocab_size, size=8 + i)
        eng.submit(np.concatenate([shared, tail]).astype(np.int32))


def _decode_hlo(eng):
    """Compiled HLO of the decode-only (T=1) program, for the collective
    traffic model."""
    B = eng.max_batch
    args = (eng.params, jnp.zeros((B, 1), jnp.int32),
            jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool),
            jnp.zeros((B,), bool), jnp.zeros((B,), bool),
            jnp.full((B,), -1, jnp.int32), jnp.zeros((B,), jnp.int32),
            eng.cache, jax.random.PRNGKey(0))
    return eng._step_fn.lower(*args).compile().as_text()


def run_arch(arch, *, n_reqs, new_tokens, budget=32, page=4):
    cfg = get_arch(arch).reduced(tp=4)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rows, ref_tokens = [], None
    for tp in TP_DEGREES:
        eng = _build(arch, params, tp, budget=budget, page=page,
                     new_tokens=new_tokens)
        _workload(eng, n_reqs)
        t0 = time.perf_counter()
        done = eng.run(max_steps=1000)
        wall = time.perf_counter() - t0
        toks = {r.request_id: list(r.output_tokens) for r in done}
        if ref_tokens is None:
            ref_tokens = toks
        pb = eng.pool_bytes()
        cs = parse_collectives(_decode_hlo(eng), default_group=tp)
        s = eng.stats
        rows.append({
            "tp": tp,
            "pool_pages": eng.pool_stats()["pool_pages"],
            "devices": pb["devices"],
            "pool_payload_total_bytes": pb["payload_total"],
            "pool_bytes_per_device": pb["per_device_max"],
            "pool_metadata_bytes": pb["metadata_total"],
            "decode_step_ms": (1e3 * s.decode_s / s.decode_steps
                               if s.decode_steps else None),
            "wall_s": round(wall, 3),
            "steps": s.steps,
            "collectives_per_decode_step": cs.counts,
            "collective_result_bytes": cs.result_bytes,
            "modelled_collective_traffic_bytes": int(cs.traffic_bytes),
            "tokens_match_tp1": toks == ref_tokens,
        })
        eng.close()
    return rows


def gate(arch, rows, errors):
    base = rows[0]
    assert base["tp"] == 1
    # one page of per-layer payload: total / pool_pages-per-layer — derive
    # from totals so the slack needs no model introspection
    # ISSUE gate: per-device bytes <= (tp=1 bytes)/tp + one page of slack.
    # pool_pages counts pages across all attention layers, so total/pages
    # IS one page of payload.
    slack = base["pool_payload_total_bytes"] // max(1, base["pool_pages"])
    for r in rows:
        if not r["tokens_match_tp1"]:
            errors.append(f"{arch} tp={r['tp']}: token parity FAILED")
        bound = base["pool_payload_total_bytes"] // r["tp"] + slack
        if r["pool_bytes_per_device"] > bound:
            errors.append(
                f"{arch} tp={r['tp']}: {r['pool_bytes_per_device']} B/device"
                f" > gate {bound} B (= total/{r['tp']} + slack)")
        if r["tp"] > 1 and not r["collectives_per_decode_step"]:
            errors.append(f"{arch} tp={r['tp']}: no collectives in the "
                          "sharded step (spec regression?)")
        unexpected = set(r["collectives_per_decode_step"]) - {"all-reduce"}
        if unexpected:
            errors.append(f"{arch} tp={r['tp']}: unexpected collective ops "
                          f"{sorted(unexpected)} (step must be psum-only)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized workload (fewer requests/tokens)")
    ap.add_argument("--json", default="BENCH_tp.json")
    args = ap.parse_args()

    if len(jax.devices()) < max(TP_DEGREES):
        print(f"need {max(TP_DEGREES)} devices, found {len(jax.devices())} "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        sys.exit(2)

    n_reqs, new_tokens = (4, 6) if args.quick else (6, 12)
    out, errors = {"archs": {}}, []
    for arch in ARCHS:
        rows = run_arch(arch, n_reqs=n_reqs, new_tokens=new_tokens)
        out["archs"][arch] = rows
        gate(arch, rows, errors)
        for r in rows:
            lat = (f"{r['decode_step_ms']:.1f}ms/step"
                   if r["decode_step_ms"] else "n/a")
            print(f"{arch:14s} tp={r['tp']}: "
                  f"{r['pool_bytes_per_device'] / 1e6:6.3f} MB/device "
                  f"(total {r['pool_payload_total_bytes'] / 1e6:.3f} MB), "
                  f"decode {lat}, AR traffic "
                  f"{r['modelled_collective_traffic_bytes']} B/step, "
                  f"parity={'OK' if r['tokens_match_tp1'] else 'FAIL'}")
    out["gate_errors"] = errors
    with open(args.json, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.json}")
    if errors:
        print("GATE FAILED:")
        for e in errors:
            print(f"  {e}")
        sys.exit(1)
    print("gate passed: per-device pool bytes <= total/tp + slack, parity "
          "exact, step is all-reduce-only")


if __name__ == "__main__":
    main()
