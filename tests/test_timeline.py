"""Per-request timeline recorder + Perfetto export (repro.obs.timeline;
DESIGN.md §10).

- recorder unit: hook calls assemble into the expected span structure
  (queue = submit → admit, indexed prefill chunks, one decode span,
  instants), times rebased to the first observation
- the exported document passes the structural Chrome-trace validation
  (what chrome://tracing / ui.perfetto.dev need to load it) and the
  validator itself rejects malformed documents
- engine integration: a shared-prefix serve run with
  ``ObsConfig(timeline=True)`` exports one engine-step span per real step,
  one request track per submission, adopt_prefix instants on the sharing
  followers, and eviction instants under budget pressure
- ``export_timeline`` refuses when the engine ran without the recorder
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.models import init_model
from repro.obs import ObsConfig, TimelineRecorder
from repro.obs.timeline import validate_chrome_trace
from repro.serving import Engine, SamplingParams


# ---------------------------------------------------------------------------
# recorder unit
# ---------------------------------------------------------------------------

def test_recorder_span_structure():
    tl = TimelineRecorder()
    tl.request_submitted("r1", 10.0)
    tl.request_admitted("r1", 10.5, slot=0, prompt_tokens=32)
    tl.prefill_chunk("r1", 10.5, 10.6, tokens=16, step=1)
    tl.prefill_chunk("r1", 10.6, 10.7, tokens=16, step=2)
    tl.decode_step("r1", 10.7)
    tl.decode_step("r1", 10.8)
    tl.request_evicted_page("r1", 10.75, page=3, lpi=1, score=0.5)
    tl.request_finished("r1", 10.9, tokens=2, reason="finished_length")
    tl.engine_step(1, "prefill", 10.5, 0.1, tokens=16)
    doc = tl.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    ev = doc["traceEvents"]
    by_name = {e["name"]: e for e in ev if e["ph"] in ("X", "i")}
    # times rebased: first observation (submit at t=10.0) is ts 0
    assert by_name["queue"]["ts"] == 0.0
    assert by_name["queue"]["dur"] == pytest.approx(0.5e6)
    assert by_name["prefill[0]"]["dur"] == pytest.approx(0.1e6, rel=1e-6)
    assert by_name["prefill[1]"]["ts"] == pytest.approx(0.6e6, rel=1e-6)
    dec = by_name["decode"]
    assert dec["ts"] == pytest.approx(0.7e6, rel=1e-6)
    assert dec["dur"] == pytest.approx(0.2e6, rel=1e-6)  # ends at finish
    assert dec["args"]["decode_steps"] == 2
    assert dec["args"]["reason"] == "finished_length"
    assert by_name["evict_page"]["args"] == {"page": 3, "lpi": 1,
                                             "score": 0.5}
    assert by_name["step:prefill"]["pid"] == 1
    # request events live on pid 2, one tid per request, with a thread name
    names = [e for e in ev if e["ph"] == "M" and e["name"] == "thread_name"]
    assert any(m["args"]["name"] == "req r1" for m in names)


def test_recorder_unadmitted_request_still_exports():
    """A request that never left the queue (engine crashed / run truncated)
    must not produce a malformed span."""
    tl = TimelineRecorder()
    tl.request_submitted("ghost", 1.0)
    doc = tl.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    assert not [e for e in doc["traceEvents"] if e["ph"] == "X"]


def test_chrome_trace_validator_catches_bad_docs():
    assert validate_chrome_trace({}) == ["missing traceEvents container"]
    assert validate_chrome_trace({"traceEvents": 3}) \
        == ["traceEvents is not a list"]
    bad_ph = {"traceEvents": [{"ph": "Z", "name": "x", "pid": 1}]}
    assert any("bad ph" in e for e in validate_chrome_trace(bad_ph))
    no_dur = {"traceEvents": [{"ph": "X", "name": "x", "pid": 1, "ts": 0}]}
    assert any("ts/dur" in e for e in validate_chrome_trace(no_dur))
    neg = {"traceEvents": [{"ph": "X", "name": "x", "pid": 1, "ts": 0,
                            "dur": -1}]}
    assert any("ts/dur" in e for e in validate_chrome_trace(neg))
    no_scope = {"traceEvents": [{"ph": "i", "name": "x", "pid": 1, "ts": 0}]}
    assert any("scope" in e for e in validate_chrome_trace(no_scope))


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _engine(policy="paged_eviction", budget=32, obs=None, max_batch=3):
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=budget, policy=policy,
                       dtype="float32")
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=max_batch,
                  max_prompt_len=48, max_new_tokens=6,
                  sampling=SamplingParams(greedy=True), chunk_size=16,
                  obs=obs)


def test_engine_timeline_export(tmp_path):
    eng = _engine(obs=ObsConfig(timeline=True, lineage=True))
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, eng.cfg.vocab_size, size=24)
    reqs = []
    for _ in range(4):
        tail = rng.integers(0, eng.cfg.vocab_size, size=12)
        reqs.append(eng.submit(np.concatenate([prefix, tail])
                               .astype(np.int32)))
    eng.run()
    out = tmp_path / "timeline.json"
    n = eng.export_timeline(str(out))
    doc = json.loads(out.read_text())
    assert validate_chrome_trace(doc) == []
    ev = doc["traceEvents"]
    assert n == len(ev)
    steps = [e for e in ev if e["ph"] == "X" and e["pid"] == 1]
    assert len(steps) == eng.stats.steps
    # one request track per submission, each with queue + decode spans
    tids = {e["tid"] for e in ev if e.get("pid") == 2 and e["ph"] == "X"}
    assert len(tids) == 4
    for name in ("queue", "decode"):
        assert sum(e["name"] == name for e in ev if e.get("pid") == 2) == 4
    # the sharing followers carry the adoption instant
    adopts = [e for e in ev if e["ph"] == "i" and e["name"] == "adopt_prefix"]
    assert len(adopts) == eng.stats.shared_prefix_hits > 0
    assert all(e["args"]["shared_tokens"] > 0 for e in adopts)
    # budget pressure surfaced as eviction instants on both pids
    assert any(e["name"] == "pages_evicted" for e in ev if e["pid"] == 1)
    req_ev = [e for e in ev if e.get("pid") == 2
              and e["name"] == "evict_page"]
    assert req_ev and all("page" in e["args"] and "lpi" in e["args"]
                          for e in req_ev)
    # spans are consistent: every complete event fits in the run
    t_end = max(e["ts"] + e.get("dur", 0) for e in ev if "ts" in e)
    assert all(e["ts"] >= 0 for e in ev if "ts" in e)
    assert t_end > 0


def test_export_timeline_requires_recorder():
    eng = _engine(obs=ObsConfig())
    with pytest.raises(ValueError, match="timeline"):
        eng.export_timeline("/tmp/never-written.json")
