"""Kernel perf pass parity (DESIGN.md §8).

Three coordinated optimizations, each pinned to an oracle:

* split-K flash-decode — the logical-page walk partitioned into independent
  flash-state chunks whose un-normalized partial softmaxes are combined
  host-side. Splits {1,2,4,8} x {f32,int8} must match the dense reference
  (and split=1) on CHURNED pools: caches decode-traced past their budget so
  freed-and-reallocated physical pages sit behind the block tables.
* G-fold prefill fetch — the paged prefill grid walks (B, KV, P) instead of
  (B, H, P), DMA-ing each K/V page once per KV-head group. The fold only
  rearranges which rows share a tile; per-row math is untouched, so the
  result is BIT-identical to the retired per-Q-head instantiation
  (``paged_flash_prefill_kernel_per_qhead``, kept as the oracle).
* fused eviction-score epilogue — decode and prefill kernels emit per-page
  K/V norm statistics as byproducts; ``ops`` reduces them to Alg.1 page
  scores that must match the standalone ``block_score`` pass
  (``ops.page_scores``) to 1e-4, including on CoW-shared prefix pages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_prefill import (
    paged_flash_prefill_kernel,
    paged_flash_prefill_kernel_per_qhead,
)

from tests.test_block_table_kernel import _dense_reference, _driven_cache
from tests.test_prefix_sharing import _adopt, _filled_cache

SPLITS = [1, 2, 4, 8]

# reduced GQA geometries of the two assigned grouped-query archs
# (arch tag, KV heads, group size G)
GQA_CONFIGS = [("mixtral-8x7b", 1, 4), ("gemma3-27b", 2, 2)]


# ---------------------------------------------------------------------------
# split-K flash-decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int8"])
@pytest.mark.parametrize("splits", SPLITS)
def test_splitk_decode_matches_dense_ref(splits, dtype):
    """Every split count reproduces the dense oracle on a churned pool
    (freed + reallocated pages behind the block table)."""
    cache, steps = _driven_cache("paged_eviction", 8, dtype)
    B, KV, hd, G = 2, 2, 64, 2
    q = jax.random.normal(jax.random.PRNGKey(11), (B, KV * G, hd))
    cur = jnp.full((B,), steps - 1, jnp.int32)
    out = np.asarray(
        ops.paged_attention(q, cache, cur_pos=cur, num_splits=splits),
        np.float32)
    exp = np.asarray(_dense_reference(q, cache, cur), np.float32)
    tol = 1e-4 if dtype == "float32" else 5e-4
    np.testing.assert_allclose(out, exp, atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_splitk_decode_split_invariant(dtype):
    """All split counts agree with split=1 to float accumulation noise —
    the combine is a pure reassociation of the same flash reduction."""
    cache, steps = _driven_cache("streaming_llm", 8, dtype, seed=5)
    q = jax.random.normal(jax.random.PRNGKey(13), (2, 4, 64))
    cur = jnp.full((2,), steps - 1, jnp.int32)
    base = np.asarray(ops.paged_attention(q, cache, cur_pos=cur,
                                          num_splits=1), np.float32)
    for s in SPLITS[1:]:
        out = np.asarray(ops.paged_attention(q, cache, cur_pos=cur,
                                             num_splits=s), np.float32)
        np.testing.assert_allclose(out, base, atol=2e-5, rtol=2e-5,
                                   err_msg=f"splits={s}")


def test_splitk_decode_windowed():
    """Split boundaries compose with the sliding-window mask."""
    cache, steps = _driven_cache("paged_eviction", 8, "float32", seed=7)
    q = jax.random.normal(jax.random.PRNGKey(17), (2, 4, 64))
    cur = jnp.full((2,), steps - 1, jnp.int32)
    for s in (1, 4):
        out = np.asarray(ops.paged_attention(q, cache, cur_pos=cur,
                                             window=8, num_splits=s))
        assert np.isfinite(out).all()
        if s == 1:
            base = out
        else:
            np.testing.assert_allclose(out, base, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# G-fold prefill fetch
# ---------------------------------------------------------------------------

def _gqa_pool(key, B, KV, G, hd, P, T, page=8):
    """Synthetic fully-churned prefill scene: pool + block table with an
    unmapped slot per row, plus chunk queries with one padding row."""
    ks = jax.random.split(key, 5)
    N = B * P + 1
    k_pool = jax.random.normal(ks[0], (KV, N, page, hd))
    v_pool = jax.random.normal(ks[1], (KV, N, page, hd))
    pos = jnp.broadcast_to(jnp.arange(page, dtype=jnp.int32)[None],
                           (N, page)) + \
        jax.random.randint(ks[2], (N, 1), 0, 3) * page
    bt = jax.random.permutation(ks[3], N - 1)[:B * P] \
        .reshape(B, P).astype(jnp.int32)
    bt = bt.at[:, P - 1].set(-1)                     # unmapped slot per row
    q = jax.random.normal(ks[4], (B, T, KV * G, hd))
    q_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                             (B, T)) + 2 * page
    q_pos = q_pos.at[0, T - 1].set(-1)               # padding query
    return q, k_pool, v_pool, pos, bt, q_pos


@pytest.mark.parametrize("arch,KV,G", GQA_CONFIGS)
def test_gfold_bit_parity_with_per_qhead_kernel(arch, KV, G):
    """The G-fold grid is BIT-identical to the per-Q-head oracle on the
    reduced GQA geometry of each assigned grouped-query arch."""
    q, k_pool, v_pool, pos, bt, q_pos = _gqa_pool(
        jax.random.PRNGKey(hash(arch) % 2**31), B=2, KV=KV, G=G, hd=64,
        P=3, T=8)
    folded = paged_flash_prefill_kernel(q, k_pool, v_pool, pos, bt, q_pos)
    per_qhead = paged_flash_prefill_kernel_per_qhead(
        q, k_pool, v_pool, pos, bt, q_pos)
    np.testing.assert_array_equal(np.asarray(folded), np.asarray(per_qhead))


def test_gfold_bit_parity_windowed():
    q, k_pool, v_pool, pos, bt, q_pos = _gqa_pool(
        jax.random.PRNGKey(23), B=1, KV=2, G=2, hd=64, P=4, T=8)
    folded = paged_flash_prefill_kernel(q, k_pool, v_pool, pos, bt, q_pos,
                                        window=12)
    per_qhead = paged_flash_prefill_kernel_per_qhead(
        q, k_pool, v_pool, pos, bt, q_pos, window=12)
    np.testing.assert_array_equal(np.asarray(folded), np.asarray(per_qhead))


@pytest.mark.parametrize("arch,KV,G", GQA_CONFIGS)
def test_gfold_on_live_pool_matches_dense_ref(arch, KV, G):
    """Decode-path cross-check on a REAL churned cache: the prefill kernel
    evaluated on a single-token chunk equals the decode dense oracle."""
    cache, steps = _driven_cache("paged_eviction", 8, "float32",
                                 KV=KV, seed=2)
    B, hd = 2, 64
    q = jax.random.normal(jax.random.PRNGKey(29), (B, 1, KV * G, hd))
    q_pos = jnp.full((B, 1), steps - 1, jnp.int32)
    out = ops.paged_prefill_attention(q, cache, q_pos=q_pos)
    exp = _dense_reference(q[:, 0], cache,
                           jnp.full((B,), steps - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused eviction-score epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int8"])
@pytest.mark.parametrize("splits", [1, 4])
def test_fused_decode_scores_match_block_score_oracle(splits, dtype):
    cache, steps = _driven_cache("paged_eviction", 8, dtype, seed=4)
    q = jax.random.normal(jax.random.PRNGKey(31), (2, 4, 64))
    cur = jnp.full((2,), steps - 1, jnp.int32)
    plain = ops.paged_attention(q, cache, cur_pos=cur, num_splits=splits)
    out, scores = ops.paged_attention(q, cache, cur_pos=cur,
                                      num_splits=splits, return_scores=True)
    # the epilogue must not perturb the attention output
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))
    oracle = ops.page_scores(cache)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(oracle),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_fused_prefill_scores_match_block_score_oracle(dtype):
    cache, steps = _driven_cache("streaming_llm", 8, dtype, seed=6)
    q = jax.random.normal(jax.random.PRNGKey(37), (2, 4, 4, 64))
    q_pos = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32)[None],
                             (2, 4)) + steps - 4
    plain = ops.paged_prefill_attention(q, cache, q_pos=q_pos)
    out, scores = ops.paged_prefill_attention(q, cache, q_pos=q_pos,
                                              return_scores=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))
    oracle = ops.page_scores(cache)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(oracle),
                               atol=1e-4, rtol=1e-4)


def test_fused_scores_on_cow_shared_pages():
    """Fused scores follow each row's own block-table VIEW of the shared
    pool: adopted prefix pages score identically for both mappers; after a
    CoW fork + token eviction the forked row's score diverges while the
    sharer's stays put — all still matching the standalone oracle."""
    from repro.core import evict_token

    cache = _filled_cache(B=2, P=3, page=4, KV=1, hd=8, rows=(0,),
                          n_tokens=8)
    cache = _adopt(cache, dst=1, src=0, n_pages=2)

    def fused(c):
        q = jax.random.normal(jax.random.PRNGKey(41), (2, 2, 8))
        _, s = ops.paged_attention(q, c, cur_pos=jnp.full((2,), 7,
                                                          jnp.int32),
                                   return_scores=True)
        return np.asarray(s)

    shared = fused(cache)
    np.testing.assert_allclose(shared, np.asarray(ops.page_scores(cache)),
                               atol=1e-4, rtol=1e-4)
    # both mappers of the shared prefix see the same page statistics
    np.testing.assert_allclose(shared[0, :2], shared[1, :2], atol=1e-6)

    # row 1 evicts a token on shared page 0 -> auto CoW fork
    cache = evict_token(cache, jnp.full((2,), 2, jnp.int32),
                        enable=jnp.asarray([False, True]))
    forked = fused(cache)
    np.testing.assert_allclose(forked, np.asarray(ops.page_scores(cache)),
                               atol=1e-4, rtol=1e-4)
    # sharer's score is untouched; the forked row's page 0 diverged
    np.testing.assert_allclose(forked[0, 0], shared[0, 0], atol=1e-6)
    assert not np.allclose(forked[1, 0], shared[1, 0])


def test_fused_scores_unmapped_slots_are_inf():
    cache = _filled_cache(B=2, P=3, page=4, KV=1, hd=8, rows=(0,),
                          n_tokens=8)
    q = jax.random.normal(jax.random.PRNGKey(43), (2, 2, 8))
    _, s = ops.paged_attention(q, cache,
                               cur_pos=jnp.full((2,), 7, jnp.int32),
                               return_scores=True)
    s = np.asarray(s)
    bt = np.asarray(cache.block_table)
    assert np.isinf(s[bt < 0]).all()
    assert np.isfinite(s[bt >= 0]).any()
