"""Chunked paged prefill + unified mixed-batch step (DESIGN.md §6).

Covers the acceptance surface of the chunked-prefill refactor:

- chunked-vs-monolithic equivalence: same surviving tokens and cache
  contents for ``full`` and ``paged_eviction`` across chunk sizes
  {64, 256, prompt_len} (monolithic == the whole prompt as one chunk).
  The regime is budget >= prompt - min_chunk so compression fires only at
  the FINAL boundary — there the incremental top-K page process provably
  equals the one-shot result; with mid-prefill eviction later chunks
  legitimately attend a pruned prefix (the paper's vLLM integration) and
  only the invariants/budget bound are asserted.
- paged flash-prefill Pallas kernel vs pure-jnp reference parity
  (atol 1e-4), on caches whose pages were freed and REALLOCATED to other
  requests mid-trace.
- forward_step(T == 1) == decode_step — the unified program really is a
  superset of the decode program.
- engine level: decode tokens are emitted WHILE a long prompt prefills,
  the insert-splice family is gone, pool invariants + budget bound hold
  after every chunk boundary, and a full mixed workload stays within the
  recompile sentinel's ceiling (``engine.programs`` gauge == 2, zero
  ``engine.unexpected_compiles`` — DESIGN.md §9).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.configs.base import ModelConfig
from repro.core import append_chunk, decode_append, get_policy, to_contiguous
from repro.models import (
    decode_step,
    forward_step,
    init_decode_caches,
    init_model,
)
from repro.serving import Engine
from repro.serving.request import RequestStatus

from tests.test_pool_invariants import _assert_pool_invariants

ATOL = 1e-4

TINY = ModelConfig(name="tiny-chunk", arch_type="dense", source="test-only",
                   num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
                   head_dim=32, d_ff=128, vocab_size=97, dtype="float32")


@pytest.fixture(scope="module")
def tiny_model():
    return TINY, init_model(jax.random.PRNGKey(0), TINY)


def _prefill_chunked(cfg, params, prompt, policy, ccfg, chunk, total_len):
    """Feed a prompt through the unified step in ``chunk``-token pieces."""
    pol = get_policy(policy)
    cache = init_decode_caches(cfg, 1, total_len, pol, ccfg,
                               chunk_tokens=chunk)
    step = jax.jit(lambda p, t, n, c: forward_step(
        p, cfg, t, n, c, pol, ccfg, prefill_mask=jnp.ones((1,), bool)))
    logits = None
    for s in range(0, len(prompt), chunk):
        piece = prompt[s:s + chunk]
        buf = np.zeros((1, chunk), np.int32)
        buf[0, :len(piece)] = piece
        logits, cache = step(params, jnp.asarray(buf),
                             jnp.asarray([len(piece)], jnp.int32), cache)
    return logits, cache


def _sorted_tokens(cache, rep):
    """(pos, k, v) of one stacked layer rep, sorted by position — physical
    placement is semantics-free, so comparisons align on positions."""
    lc = jax.tree.map(lambda a: a[rep], cache.pattern[0].kv)
    k, v, pos, valid = [np.asarray(a[0]) for a in to_contiguous(lc)]
    order = np.argsort(np.where(valid, pos, np.iinfo(np.int32).max),
                       kind="stable")
    n = int(valid.sum())
    return pos[order][:n], k[order][:n], v[order][:n]


@pytest.mark.parametrize("policy", ["full", "paged_eviction"])
def test_chunked_vs_monolithic_equivalence(tiny_model, policy):
    """Chunk sizes {64, 256, prompt_len}: identical surviving tokens, cache
    contents (every layer), and final-token logits."""
    cfg, params = tiny_model
    prompt_len = 320
    prompt = (np.arange(prompt_len, dtype=np.int32) * 7) % cfg.vocab_size
    ccfg = CacheConfig(page_size=16, cache_budget=256, policy=policy,
                       dtype="float32")
    ref_lg, ref_cache = _prefill_chunked(cfg, params, prompt, policy, ccfg,
                                         prompt_len, prompt_len + 8)
    if policy == "paged_eviction":
        # compression actually fired: 320 tokens -> 16 full pages = budget
        p0, _, _ = _sorted_tokens(ref_cache, 0)
        assert len(p0) == 256, len(p0)
    for chunk in (64, 256):
        lg, cache = _prefill_chunked(cfg, params, prompt, policy, ccfg,
                                     chunk, prompt_len + 8)
        for rep in range(cfg.num_layers):
            p1, k1, v1 = _sorted_tokens(ref_cache, rep)
            p2, k2, v2 = _sorted_tokens(cache, rep)
            np.testing.assert_array_equal(p1, p2,
                                          err_msg=f"{policy} chunk {chunk}")
            np.testing.assert_allclose(k1, k2, atol=ATOL, rtol=ATOL)
            np.testing.assert_allclose(v1, v2, atol=ATOL, rtol=ATOL)
        np.testing.assert_allclose(np.asarray(ref_lg), np.asarray(lg),
                                   atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("policy", ["paged_eviction", "streaming_llm",
                                    "inverse_key_l2"])
def test_mid_prefill_eviction_invariants_and_budget(tiny_model, policy):
    """Budget << prompt: compression fires at EVERY boundary. Exact
    equivalence is out (later chunks attend a pruned prefix — the paper's
    chunked integration); what must hold after every boundary: pool
    invariants F1-F4 and the budget bound."""
    cfg, params = tiny_model
    prompt = (np.arange(160, dtype=np.int32) * 11) % cfg.vocab_size
    budget, page, chunk = 64, 16, 32
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype="float32")
    pol = get_policy(policy)
    cache = init_decode_caches(cfg, 1, 200, pol, ccfg, chunk_tokens=chunk)
    for s in range(0, len(prompt), chunk):
        buf = np.zeros((1, chunk), np.int32)
        piece = prompt[s:s + chunk]
        buf[0, :len(piece)] = piece
        _, cache = forward_step(params, cfg, jnp.asarray(buf),
                                jnp.asarray([len(piece)], jnp.int32), cache,
                                pol, ccfg, prefill_mask=jnp.ones((1,), bool))
        for rep in range(cfg.num_layers):
            lc = jax.tree.map(lambda a: a[rep], cache.pattern[0].kv)
            _assert_pool_invariants(lc, f"{policy} boundary {s}")
            tv = int(np.asarray(lc.total_valid())[0])
            assert tv <= budget + page, (policy, s, tv)


def test_forward_step_T1_matches_decode_step(tiny_model):
    """The unified program at T == 1 with a decode row reproduces
    decode_step exactly (same Alg.3 bookkeeping, same attention)."""
    cfg, params = tiny_model
    policy = "paged_eviction"
    ccfg = CacheConfig(page_size=16, cache_budget=32, policy=policy,
                       dtype="float32")
    pol = get_policy(policy)
    prompt = (np.arange(48, dtype=np.int32) * 5) % cfg.vocab_size
    _, cache = _prefill_chunked(cfg, params, prompt, policy, ccfg, 16, 96)
    tok = jnp.asarray([[3]], jnp.int32)
    for _ in range(12):
        lg_a, cache_a = decode_step(params, cfg, tok[:, 0], cache, pol, ccfg)
        lg_b, cache_b = forward_step(
            params, cfg, tok, jnp.asarray([1], jnp.int32), cache, pol, ccfg,
            decode_mask=jnp.ones((1,), bool),
            prefill_mask=jnp.zeros((1,), bool))
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                                   atol=1e-5, rtol=1e-5)
        for rep in range(cfg.num_layers):
            a = jax.tree.map(lambda x: x[rep], cache_a.pattern[0].kv)
            b = jax.tree.map(lambda x: x[rep], cache_b.pattern[0].kv)
            np.testing.assert_array_equal(np.asarray(a.pos),
                                          np.asarray(b.pos))
            np.testing.assert_array_equal(np.asarray(a.block_table),
                                          np.asarray(b.block_table))
        cache = cache_a
        tok = jnp.argmax(lg_a, -1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# paged flash-prefill kernel parity
# ---------------------------------------------------------------------------

def _churned_cache(policy="paged_eviction", page=8, B=2, KV=2, hd=64, seed=0):
    """Decode-trace a pooled cache far past budget so physical pages are
    freed and REALLOCATED across requests, then it is chunk-ready."""
    budget = 2 * page
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    pol = get_policy(policy)
    steps = budget + 3 * page + 3
    from repro.core import init_layer_cache
    pages = pol.slab_pages(cfg, steps) + 3          # chunk headroom
    cache = init_layer_cache(B, pages, page, KV, hd, jnp.float32)
    rng = jax.random.PRNGKey(seed)
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        cache = decode_append(cache, jax.random.normal(k1, (B, KV, hd)),
                              jax.random.normal(k2, (B, KV, hd)),
                              jnp.full((B,), t), pol, cfg).cache
    return cache, steps


@pytest.mark.parametrize("window", [0, 16])
def test_paged_flash_prefill_kernel_matches_refs(window):
    """Kernel vs jnp oracle vs model-layer oracle on a chunk appended to a
    cache that straddles freed-and-reallocated pages; one row shorter than
    the chunk exercises padding-query masking."""
    from repro.kernels import ops, ref
    from repro.models.attention import paged_attention_chunk_ref

    B, KV, G, hd, T = 2, 2, 2, 64, 16
    cache, steps = _churned_cache(page=8, B=B, KV=KV, hd=hd)
    rng = jax.random.PRNGKey(42)
    n_tok = jnp.array([T, T - 5])
    q_pos = steps + jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    q_pos = jnp.where(jnp.arange(T)[None] < n_tok[:, None], q_pos, -1)
    kc = jax.random.normal(rng, (B, T, KV, hd))
    vc = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, KV, hd))
    cache = append_chunk(cache, kc, vc, q_pos, jnp.zeros((B, T)), n_tok)
    q = jax.random.normal(jax.random.fold_in(rng, 2), (B, T, KV * G, hd))

    out = np.asarray(ops.paged_prefill_attention(q, cache, q_pos=q_pos,
                                                 window=window))
    oracle = np.asarray(ref.paged_prefill_attention_block_table_ref(
        q.reshape(B, T, KV, G, hd), jnp.moveaxis(cache.k, 2, 0),
        jnp.moveaxis(cache.v, 2, 0), cache.pos, cache.block_table, q_pos,
        window=window).reshape(B, T, KV * G, hd))
    model_ref = np.asarray(paged_attention_chunk_ref(q, cache, q_pos=q_pos,
                                                     window=window))
    np.testing.assert_allclose(out, oracle, atol=ATOL, rtol=ATOL)
    np.testing.assert_allclose(out, model_ref, atol=ATOL, rtol=ATOL)
    # padding queries emit exactly zero
    assert (out[1, T - 5:] == 0).all()


def test_paged_flash_prefill_kernel_isolates_requests():
    """Each chunk row must only see its own block table even though the
    pool interleaves requests' pages after churn."""
    from repro.kernels import ops

    B, KV, G, hd, T = 3, 2, 2, 64, 8
    cache, steps = _churned_cache(page=8, B=B, seed=5)
    rng = jax.random.PRNGKey(7)
    q_pos = steps + jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    kc = jax.random.normal(rng, (B, T, KV, hd))
    cache = append_chunk(cache, kc, kc, q_pos, jnp.zeros((B, T)),
                         jnp.full((B,), T))
    q = jax.random.normal(jax.random.fold_in(rng, 3), (B, T, KV * G, hd))
    batched = np.asarray(ops.paged_prefill_attention(q, cache, q_pos=q_pos))
    for b in range(B):
        solo_cache = cache._replace(block_table=cache.block_table[b:b + 1],
                                    cur_page=cache.cur_page[b:b + 1],
                                    cur_off=cache.cur_off[b:b + 1])
        solo = np.asarray(ops.paged_prefill_attention(
            q[b:b + 1], solo_cache, q_pos=q_pos[b:b + 1]))
        np.testing.assert_allclose(batched[b:b + 1], solo, atol=ATOL)


# ---------------------------------------------------------------------------
# engine level: the acceptance scenario
# ---------------------------------------------------------------------------

def _engine_layer_caches(eng):
    for lc in list(eng.cache.pattern):
        R = jax.tree.leaves(lc.kv)[0].shape[0]
        for rep in range(R):
            yield jax.tree.map(lambda a: a[rep], lc.kv)
    for lc in eng.cache.tail:
        if lc.kv is not None:
            yield lc.kv


def test_decode_interleaves_with_long_prefill():
    """1 long prompt + 7 active decode slots: decode tokens are emitted
    DURING the long prompt's prefill, the insert splice is gone, and pool
    invariants + budget bound hold after every chunk boundary."""
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    budget, page = 32, 8
    ccfg = CacheConfig(page_size=page, cache_budget=budget,
                       policy="paged_eviction", dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=8, max_prompt_len=64,
                 max_new_tokens=40, chunk_size=8)

    # the splice family is dead
    from repro.models import transformer
    assert not hasattr(eng, "_insert_fn")
    assert not hasattr(eng, "_prefill_fn")
    assert not hasattr(transformer, "insert_request_cache")

    rng = np.random.default_rng(1)
    short = [eng.submit(rng.integers(0, cfg.vocab_size, size=6)
                        .astype(np.int32)) for _ in range(7)]
    # bring all 7 to RUNNING
    for _ in range(4):
        eng.step()
        if all(r.status == RequestStatus.RUNNING for r in short):
            break
    assert all(r.status == RequestStatus.RUNNING for r in short)

    long_req = eng.submit(rng.integers(0, cfg.vocab_size, size=64)
                          .astype(np.int32))
    gen_before = sum(r.num_generated for r in short)
    prefill_steps = 0
    while long_req.status == RequestStatus.PREFILLING or \
            long_req.status == RequestStatus.WAITING:
        assert eng.step()
        prefill_steps += 1
        for i, lc in enumerate(_engine_layer_caches(eng)):
            _assert_pool_invariants(lc, f"step {prefill_steps} layer {i}")
            tv = np.asarray(lc.total_valid())
            # chunk boundaries keep every row within budget + page slack
            assert (tv <= budget + page).all(), (prefill_steps, i, tv)
        assert prefill_steps < 64, "long prompt never finished prefilling"
    gen_during = sum(r.num_generated for r in short) - gen_before
    # 64-token prompt / 8-token chunks spread over >= 8 steps, and the
    # decode slots kept emitting THROUGHOUT — the old engine emitted 0 here
    assert prefill_steps >= 8, prefill_steps
    assert gen_during >= 7 * (prefill_steps - 1), (gen_during, prefill_steps)
    assert long_req.num_generated >= 1          # TTFT token emitted

    eng.run()
    assert long_req.finished and all(r.finished for r in short)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "xlstm-1.3b",
                                  "mixtral-8x7b", "gemma3-27b"])
def test_unified_step_serves_heterogeneous_archs(arch):
    """forward_step's recurrent-scan / MoE / windowed-attention branches:
    hybrid (mamba+attn+moe), xLSTM, MoE, and local/global interleave all
    serve end-to-end through the chunked engine."""
    cfg = ASSIGNED_ARCHS[arch].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=2, max_prompt_len=32,
                 max_new_tokens=4, chunk_size=8)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=n)
                       .astype(np.int32)) for n in (20, 11, 26)]
    done = eng.run()
    assert len(done) == 3
    for r in reqs:
        assert r.finished and r.num_generated == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output_tokens)


def test_engine_recompile_sentinel():
    """Full mixed workload (admissions, mixed steps, decode-only steps,
    retirements, re-admissions) stays within the recompile sentinel's
    ceiling: the ``engine.programs`` gauge reads exactly 2 (T == chunk and
    T == 1 — the static_argnames=("slot",) recompilation family is extinct)
    and no step tripped ``engine.unexpected_compiles``."""
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=3, max_prompt_len=48,
                 max_new_tokens=6, chunk_size=16)
    rng = np.random.default_rng(3)
    for n in (4, 30, 47, 9, 21, 40):            # forces re-admission churn
        eng.submit(rng.integers(0, cfg.vocab_size, size=n).astype(np.int32))
    done = eng.run()
    assert len(done) == 6
    assert eng.num_compiled_programs() != -1, \
        "program-count introspection unavailable"
    snap = eng.metrics_snapshot()
    assert snap["engine.programs"]["value"] == 2, snap["engine.programs"]
    assert "engine.unexpected_compiles" not in snap, \
        snap.get("engine.unexpected_compiles")
