"""Page-lineage ledger (repro.obs.lineage; DESIGN.md §10).

The contract under test: diffing per-step device snapshots of the tracked
attention layer, the ledger's replayed block table and derived ref counts
reconcile EXACTLY with the device state after EVERY step of a churned
workload (shared-prefix adoptions, CoW forks, page evictions, retirements
and slot reuse). Count cross-checks against the devstats vector are
inequalities (within-step churn and multi-layer totals), state
reconciliation is the exact gate.

Also: event-record round-trip through the v2 trace schema, offline ledger
reconstruction from a trace file, and the per-request loss report.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.models import init_model
from repro.obs import ObsConfig, PageLineageLedger, StepPlanContext
from repro.obs.lineage import PageEvent
from repro.obs.trace import validate_event, validate_file
from repro.serving import Engine, SamplingParams


def _engine(policy="paged_eviction", budget=32, trace=None, max_batch=3,
            new_tokens=8):
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=budget, policy=policy,
                       dtype="float32")
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=max_batch,
                  max_prompt_len=48, max_new_tokens=new_tokens,
                  sampling=SamplingParams(greedy=True), chunk_size=16,
                  obs=ObsConfig(lineage=True, trace_path=trace))


def _churned_run(eng, *, check_every_step=True, seed=7, n_reqs=6):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, eng.cfg.vocab_size, size=24)
    for _ in range(n_reqs):
        tail = rng.integers(0, eng.cfg.vocab_size,
                            size=int(rng.integers(6, 20)))
        eng.submit(np.concatenate([prefix, tail]).astype(np.int32))
    steps = 0
    while eng.step() and steps < 300:
        steps += 1
        if check_every_step:
            snap = jax.device_get(eng._lineage_fn(eng.cache))
            assert eng.obs.ledger.reconcile(snap) == [], f"step {steps}"
    assert len(eng.scheduler.finished) == n_reqs
    return steps


@pytest.mark.parametrize("policy", ["paged_eviction", "streaming_llm"])
def test_ledger_reconciles_every_step(policy):
    """Exact block-table + ref-count agreement after every step, for both a
    page policy (evict + rollover recycling, the hard case) and a token
    policy (CoW forks under eviction)."""
    eng = _engine(policy)
    _churned_run(eng)
    counts = eng.obs.ledger.counts()
    assert counts.get("adopt", 0) > 0, "workload never exercised adoption"
    assert counts.get("release", 0) > 0, "retirement never released pages"
    if policy == "paged_eviction":
        assert counts.get("evict", 0) > 0, "no evictions under pressure"
    else:
        assert counts.get("fork", 0) > 0, \
            "token eviction on shared pages must CoW-fork"


def test_ledger_counts_bounded_by_devstats():
    """The tracked layer's event counts cannot exceed the fleet-wide
    devstats totals (which sum every layer's churn)."""
    eng = _engine("paged_eviction")
    _churned_run(eng, check_every_step=False)
    counts = eng.obs.ledger.counts()
    reg = eng.obs.registry
    assert counts.get("evict", 0) <= reg.counter("pool.pages_evicted").value
    assert counts.get("adopt", 0) <= reg.counter("pool.pages_adopted").value
    assert counts.get("fork", 0) <= reg.counter("pool.pages_forked").value


def test_evict_events_carry_policy_scores():
    """Every paged_eviction victim is priced: the event records the victim
    page's policy score from the pre-step snapshot, plus the tokens and
    base position lost."""
    eng = _engine("paged_eviction")
    _churned_run(eng, check_every_step=False)
    evicts = [ev for ev in eng.obs.ledger.events if ev.etype == "evict"]
    assert evicts
    scored = [ev for ev in evicts if ev.score is not None]
    assert scored, "no evict event carried a policy score"
    for ev in scored:
        assert np.isfinite(ev.score)
        assert ev.tokens is not None and ev.tokens >= 0
    # loss report over the slots that lost pages
    slots = {ev.slot for ev in evicts}
    total = 0
    for slot in slots:
        rep = eng.obs.ledger.request_loss_report(slot)
        total += rep["pages_lost"]
        assert rep["tokens_lost"] >= 0
        for lo, hi in rep["positions"]:
            assert 0 <= lo <= hi
        if rep["mean_evict_score"] is not None:
            assert np.isfinite(rep["mean_evict_score"])
    assert total == len(evicts)


def test_page_history_tracks_reuse():
    """A physical page's history spans owners: after a release the same
    page id may be re-allocated to another slot — the history lists both
    lives in step order."""
    eng = _engine("paged_eviction")
    _churned_run(eng, check_every_step=False)
    led = eng.obs.ledger
    pages = {ev.page for ev in led.events}
    reused = [g for g in pages
              if len([e for e in led.page_history(g)
                      if e.etype in ("alloc", "adopt")]) > 1]
    assert reused, "6 requests through 3 slots never reused a page"
    hist = led.page_history(reused[0])
    assert [e.step for e in hist] == sorted(e.step for e in hist)


def test_event_records_validate_and_roundtrip():
    ev = PageEvent(step=3, etype="evict", page=7, slot=1, lpi=2, score=0.25,
                   tokens=8, pos=16)
    rec = ev.to_record()
    assert validate_event(rec) == []
    assert PageEvent.from_record(rec) == ev
    assert validate_event(dict(rec, etype="bogus"))
    assert validate_event(dict(rec, score="high"))


def test_ledger_rebuild_from_trace(tmp_path):
    """Offline forensics: the v2 event records written into the trace are
    sufficient to rebuild the ledger — same final block table, same event
    counts, same loss reports — with no device access."""
    trace = tmp_path / "t.jsonl"
    eng = _engine("paged_eviction", trace=str(trace))
    _churned_run(eng, check_every_step=False)
    eng.close()
    assert validate_file(str(trace)) == []
    live = eng.obs.ledger
    B, P = live.replayed_block_table().shape
    rebuilt = PageLineageLedger.from_trace(
        str(trace), batch=B, num_pages=P, pool_pages=live._pool_pages)
    assert np.array_equal(rebuilt.replayed_block_table(),
                          live.replayed_block_table())
    assert np.array_equal(rebuilt.replayed_ref_count(),
                          live.replayed_ref_count())
    assert rebuilt.counts() == live.counts()
    for slot in range(B):
        a = rebuilt.request_loss_report(slot)
        b = live.request_loss_report(slot)
        assert (a["pages_lost"], a["tokens_lost"], a["positions"]) \
            == (b["pages_lost"], b["tokens_lost"], b["positions"])
    # the trace interleaves step + event records on one stream
    recs = [json.loads(ln) for ln in trace.read_text().splitlines()]
    kinds = {r.get("rec") for r in recs}
    assert kinds == {"step", "event"}


def test_reconcile_reports_mismatches():
    led = PageLineageLedger()
    snap = {"block_table": np.array([[0, -1]]), "ref_count": np.array([1, 0]),
            "cur_page": np.array([0]), "tokens_per_page": np.array([[3, 0]]),
            "page_scores": np.array([[0.5, np.inf]]),
            "pos_base": np.array([[0, -1]])}
    assert led.reconcile(snap) == ["ledger has observed no steps"]
    led.observe_step(1, snap, StepPlanContext())
    assert led.reconcile(snap) == []
    wrong = dict(snap, block_table=np.array([[1, -1]]),
                 ref_count=np.array([0, 1]))
    errs = led.reconcile(wrong)
    assert any("block_table" in e for e in errs)
    assert any("ref_count" in e for e in errs)
