"""Unit tests for the functional pooled paged KV cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paged_cache as pc


def _cache(B=2, P=4, page=4, KV=2, hd=8):
    return pc.init_layer_cache(B, P, page, KV, hd, jnp.float32)


def test_init_premaps_working_page():
    c = _cache()
    bt = np.asarray(c.block_table)
    np.testing.assert_array_equal(bt[:, 0], [0, 1])      # distinct pool pages
    assert (bt[:, 1:] == -1).all()
    assert int(c.num_free()) == c.pool_pages - 2
    assert c.pool_pages == 2 * 4


def test_write_token_places_at_head():
    c = _cache()
    B, KV, hd = 2, 2, 8
    k = jnp.ones((B, KV, hd))
    v = 2 * jnp.ones((B, KV, hd))
    c = pc.write_token(c, k, v, jnp.array([0, 0]), jnp.array([1.0, 2.0]))
    assert int(c.cur_off[0]) == 1
    np.testing.assert_array_equal(np.asarray(c.pos_view()[:, 0, 0]), [0, 0])
    assert float(c.score_view()[1, 0, 0]) == 2.0
    assert int(c.total_valid()[0]) == 1


def test_write_token_respects_active_mask():
    c = _cache()
    k = jnp.ones((2, 2, 8))
    c = pc.write_token(c, k, k, jnp.array([5, 5]), jnp.zeros(2),
                       active=jnp.array([True, False]))
    assert int(c.total_valid()[0]) == 1
    assert int(c.total_valid()[1]) == 0
    assert int(c.cur_off[1]) == 0


def test_page_scores_mean_and_inf_for_empty():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.full((2,), float(i)))
    ps = np.asarray(c.page_scores())
    assert np.allclose(ps[:, 0], 1.5)              # mean(0,1,2,3)
    assert np.isinf(ps[:, 1:]).all()


def test_evict_page_returns_to_free_list():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
    free_before = int(c.num_free())
    c = pc.evict_page(c, jnp.array([0, 0]))
    assert int(c.total_valid()[0]) == 0
    assert int(c.num_free()) == free_before + 2    # both pages back in pool
    assert (np.asarray(c.block_table)[:, 0] == -1).all()
    # the freed physical pages hold no live tokens (invariant F4)
    ref = np.asarray(c.ref_count)
    assert (np.asarray(c.pos)[ref == 0] == -1).all()
    # and can be re-allocated
    c2, phys, ok = pc.alloc_pages(c, jnp.array([True, True]))
    assert bool(ok.all())
    assert len(set(np.asarray(phys).tolist())) == 2


def test_alloc_pages_distinct_and_bounded():
    c = _cache(B=3, P=2)                            # pool = 6, 3 pre-mapped
    c, phys, ok = pc.alloc_pages(c, jnp.array([True, False, True]))
    p = np.asarray(phys)
    assert bool(ok[0]) and not bool(ok[1]) and bool(ok[2])
    assert p[0] != p[2] and p[1] == c.pool_pages    # sentinel where not needed
    # exhaust the pool: only 1 free page left now
    c, phys2, ok2 = pc.alloc_pages(c, jnp.array([True, True, True]))
    assert int(np.asarray(ok2).sum()) == 1


def test_evict_token_flat_index():
    c = _cache()
    for i in range(6):                              # fills page0 + 2 of page1
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
        if int(c.cur_off[0]) == c.page_size:
            c2, phys, ok = pc.alloc_pages(c, jnp.ones((2,), bool))
            c = pc.start_new_page(c2, jnp.array([1, 1]), phys, ok)
    c = pc.evict_token(c, jnp.array([2, 5]))        # page0/off2 ; page1/off1
    pos = np.asarray(c.pos_view())
    assert pos[0, 0, 2] == -1 and pos[1, 1, 1] == -1
    assert int(c.total_valid()[0]) == 5


def test_reclaim_empty_pages():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
    c2, phys, ok = pc.alloc_pages(c, jnp.ones((2,), bool))
    c = pc.start_new_page(c2, jnp.array([1, 1]), phys, ok)
    # token-evict page 0 empty, one token at a time (stays mapped)
    for j in range(4):
        c = pc.evict_token(c, jnp.array([j, j]))
    assert (np.asarray(c.block_table)[:, 0] >= 0).all()
    c = pc.reclaim_empty_pages(c)
    assert (np.asarray(c.block_table)[:, 0] == -1).all()
    ref = np.asarray(c.ref_count)
    mapped = np.asarray(c.block_table)
    assert int((ref > 0).sum()) == (mapped >= 0).sum()


def test_to_contiguous_roundtrip():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.full((2, 2, 8), float(i)),
                           jnp.full((2, 2, 8), float(i)),
                           jnp.full((2,), i), jnp.zeros(2))
    k, v, pos, mask = pc.to_contiguous(c)
    assert k.shape == (2, 16, 2, 8)
    assert int(mask.sum()) == 8
    got = sorted(np.asarray(pos[0])[np.asarray(mask[0])].tolist())
    assert got == [0, 1, 2, 3]


def test_write_prompt_pages_layout():
    c = _cache(P=4, page=4)
    C = 8
    k = jnp.arange(2 * C * 2 * 8, dtype=jnp.float32).reshape(2, C, 2, 8)
    pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (2, C))
    score = jnp.ones((2, C))
    c = pc.write_prompt_pages(c, k, k, pos, score)
    assert int(c.cur_page[0]) == 2 and int(c.cur_off[0]) == 0
    assert int(c.total_valid()[0]) == C
    pv = np.asarray(c.pos_view())
    np.testing.assert_array_equal(pv[0, 0], [0, 1, 2, 3])
    np.testing.assert_array_equal(pv[0, 1], [4, 5, 6, 7])
    assert np.isinf(np.asarray(c.page_scores())[0, 2:]).all()
    # the decode working page is mapped (so write_token has a target), and
    # block tables never share physical pages
    bt = np.asarray(c.block_table)
    assert (bt[:, :3] >= 0).all() and (bt[:, 3] == -1).all()
    mapped = bt[bt >= 0]
    assert len(mapped) == len(set(mapped.tolist()))


def test_append_chunk_matches_sequential_writes():
    """append_chunk (the unified-step write path) must produce exactly the
    cache a per-token write_token + rollover sequence produces — pages
    filled in order, fresh pages from the free list at each boundary."""
    B, P, page, T = 2, 4, 4, 10
    c = _cache(B=B, P=P, page=page)
    rng = jax.random.PRNGKey(0)
    k = jax.random.normal(rng, (B, T, 2, 8))
    v = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    n_tok = jnp.array([T, 7])
    pos = jnp.where(jnp.arange(T)[None] < n_tok[:, None], pos, -1)
    score = jnp.zeros((B, T))
    out = pc.append_chunk(c, k, v, pos, score, n_tok)

    seq = c
    for t in range(T):
        act = jnp.arange(T)[t] < n_tok
        seq = pc.chunk_rollover(seq, act & (seq.cur_off >= seq.page_size))
        seq = pc.write_token(seq, k[:, t], v[:, t], pos[:, t], score[:, t],
                             active=act)
    for name in ("k", "v", "pos", "score", "block_table", "ref_count",
                 "cur_page", "cur_off"):
        np.testing.assert_array_equal(np.asarray(getattr(out, name)),
                                      np.asarray(getattr(seq, name)),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(out.total_valid()), [T, 7])


def test_append_chunk_allocates_from_shared_free_list():
    """A chunk spanning several pages draws distinct pool pages per rollover
    and conserves the free list (F1-F3)."""
    B, P, page = 2, 4, 4
    c = _cache(B=B, P=P, page=page)
    T = 3 * page
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    c = pc.append_chunk(c, jnp.ones((B, T, 2, 8)), jnp.ones((B, T, 2, 8)),
                        pos, jnp.zeros((B, T)), jnp.full((B,), T))
    assert (np.asarray(c.total_valid()) == T).all()
    bt = np.asarray(c.block_table)
    mapped = bt[bt >= 0]
    assert len(mapped) == len(set(mapped.tolist()))          # F3
    ref = np.asarray(c.ref_count)
    np.testing.assert_array_equal(np.bincount(mapped, minlength=c.pool_pages),
                                  ref)                       # F2
    assert int((ref > 0).sum()) + int(c.num_free()) == c.pool_pages  # F1


def test_release_rows_returns_pages_and_rearms_head():
    """release_rows frees a retiring row's pages to the SHARED pool and
    parks the head so the next append re-allocates from the free list."""
    B, P, page = 2, 4, 4
    c = _cache(B=B, P=P, page=page)
    T = 2 * page
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    c = pc.append_chunk(c, jnp.ones((B, T, 2, 8)), jnp.ones((B, T, 2, 8)),
                        pos, jnp.zeros((B, T)), jnp.full((B,), T))
    free0 = int(c.num_free())
    c = pc.release_rows(c, jnp.array([True, False]))
    assert int(c.num_free()) == free0 + 2   # both full pages back in the pool
    assert (np.asarray(c.block_table)[0] == -1).all()
    assert int(c.total_valid()[0]) == 0
    assert int(c.total_valid()[1]) == T     # other row untouched
    # a fresh request appends into the released row: first write rolls onto
    # a freshly allocated page (no dangling head)
    c = pc.append_chunk(c, jnp.ones((B, 3, 2, 8)), jnp.ones((B, 3, 2, 8)),
                        jnp.broadcast_to(jnp.arange(3, dtype=jnp.int32), (B, 3)),
                        jnp.zeros((B, 3)), jnp.array([3, 0]))
    assert int(c.total_valid()[0]) == 3
    bt = np.asarray(c.block_table)
    mapped = bt[bt >= 0]
    assert len(mapped) == len(set(mapped.tolist()))


def test_append_chunk_force_evicts_when_pool_dry():
    """Unstructured token policies can pin every logical slot with
    one-token survivor pages; the chunk rollover must then force-evict the
    fewest-token page rather than silently drop the incoming K/V."""
    B, P, page = 1, 3, 4
    c = _cache(B=B, P=P, page=page)                 # pool == 3 pages
    T = 3 * page
    pos = jnp.arange(T, dtype=jnp.int32)[None]
    c = pc.append_chunk(c, jnp.ones((B, T, 2, 8)), jnp.ones((B, T, 2, 8)),
                        pos, jnp.zeros((B, T)), jnp.full((B,), T))
    # fragment: keep exactly one token per page (offsets 1..3 evicted)
    frag = jnp.broadcast_to(jnp.arange(page) > 0, (B, P, page))
    c = pc.evict_token_mask(c, frag)
    assert int(c.total_valid()[0]) == P
    assert int(c.num_free()) == 0                   # every slot pinned
    new_pos = T + jnp.arange(page, dtype=jnp.int32)[None]
    c = pc.append_chunk(c, jnp.ones((B, page, 2, 8)),
                        jnp.ones((B, page, 2, 8)), new_pos,
                        jnp.zeros((B, page)), jnp.full((B,), page))
    got = np.asarray(c.pos_view()[0]).reshape(-1)
    for p_ in range(T, T + page):                   # the chunk LANDED
        assert p_ in got, (p_, got)
    # one survivor page was force-evicted to make room
    assert int(c.total_valid()[0]) == P - 1 + page
    ref = np.asarray(c.ref_count)
    bt = np.asarray(c.block_table)
    mapped = bt[bt >= 0]
    np.testing.assert_array_equal(np.bincount(mapped, minlength=c.pool_pages),
                                  ref)
    assert (np.asarray(c.pos)[ref == 0] == -1).all()


def test_evict_pages_mask_multi_victim():
    B, P, page = 2, 4, 4
    c = _cache(B=B, P=P, page=page)
    T = 3 * page
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    c = pc.append_chunk(c, jnp.ones((B, T, 2, 8)), jnp.ones((B, T, 2, 8)),
                        pos, jnp.zeros((B, T)), jnp.full((B,), T))
    mask = jnp.array([[True, True, False, False],
                      [False, False, False, False]])
    free0 = int(c.num_free())
    c = pc.evict_pages_mask(c, mask)
    assert int(c.num_free()) == free0 + 2
    assert int(c.total_valid()[0]) == page
    assert int(c.total_valid()[1]) == T
    ref = np.asarray(c.ref_count)
    assert (np.asarray(c.pos)[ref == 0] == -1).all()         # F4
