"""Unit tests for the functional paged KV cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paged_cache as pc


def _cache(B=2, P=4, page=4, KV=2, hd=8):
    return pc.init_layer_cache(B, P, page, KV, hd, jnp.float32)


def test_write_token_places_at_head():
    c = _cache()
    B, KV, hd = 2, 2, 8
    k = jnp.ones((B, KV, hd))
    v = 2 * jnp.ones((B, KV, hd))
    c = pc.write_token(c, k, v, jnp.array([0, 0]), jnp.array([1.0, 2.0]))
    assert int(c.cur_off[0]) == 1
    np.testing.assert_array_equal(np.asarray(c.pos[:, 0, 0]), [0, 0])
    assert float(c.score[1, 0, 0]) == 2.0
    assert int(c.total_valid()[0]) == 1


def test_write_token_respects_active_mask():
    c = _cache()
    k = jnp.ones((2, 2, 8))
    c = pc.write_token(c, k, k, jnp.array([5, 5]), jnp.zeros(2),
                       active=jnp.array([True, False]))
    assert int(c.total_valid()[0]) == 1
    assert int(c.total_valid()[1]) == 0
    assert int(c.cur_off[1]) == 0


def test_page_scores_mean_and_inf_for_empty():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.full((2,), float(i)))
    ps = np.asarray(c.page_scores())
    assert np.allclose(ps[:, 0], 1.5)              # mean(0,1,2,3)
    assert np.isinf(ps[:, 1:]).all()


def test_evict_page_and_reuse():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
    c = pc.evict_page(c, jnp.array([0, 0]))
    assert int(c.total_valid()[0]) == 0
    idx, exists = pc.find_free_page(c)
    assert bool(exists.all())
    c = pc.start_new_page(c, idx)
    assert int(c.cur_off[0]) == 0


def test_evict_token_flat_index():
    c = _cache()
    for i in range(6):                              # fills page0 + 2 of page1
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
        out = c
        if int(c.cur_off[0]) == c.page_size:
            c = pc.start_new_page(c, jnp.array([1, 1]))
    c = pc.evict_token(c, jnp.array([2, 5]))        # page0/off2 ; page1/off1
    pos = np.asarray(c.pos)
    assert pos[0, 0, 2] == -1 and pos[1, 1, 1] == -1
    assert int(c.total_valid()[0]) == 5


def test_to_contiguous_roundtrip():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.full((2, 2, 8), float(i)),
                           jnp.full((2, 2, 8), float(i)),
                           jnp.full((2,), i), jnp.zeros(2))
    k, v, pos, mask = pc.to_contiguous(c)
    assert k.shape == (2, 16, 2, 8)
    assert int(mask.sum()) == 8
    got = sorted(np.asarray(pos[0])[np.asarray(mask[0])].tolist())
    assert got == [0, 1, 2, 3]


def test_write_prompt_pages_layout():
    c = _cache(P=4, page=4)
    C = 8
    k = jnp.arange(2 * C * 2 * 8, dtype=jnp.float32).reshape(2, C, 2, 8)
    pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (2, C))
    score = jnp.ones((2, C))
    c = pc.write_prompt_pages(c, k, k, pos, score)
    assert int(c.cur_page[0]) == 2 and int(c.cur_off[0]) == 0
    assert int(c.total_valid()[0]) == C
    np.testing.assert_array_equal(np.asarray(c.pos[0, 0]), [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(c.pos[0, 1]), [4, 5, 6, 7])
    assert np.isinf(np.asarray(c.page_scores())[0, 2:]).all()
