"""Unit tests for the functional pooled paged KV cache."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paged_cache as pc


def _cache(B=2, P=4, page=4, KV=2, hd=8):
    return pc.init_layer_cache(B, P, page, KV, hd, jnp.float32)


def test_init_premaps_working_page():
    c = _cache()
    bt = np.asarray(c.block_table)
    np.testing.assert_array_equal(bt[:, 0], [0, 1])      # distinct pool pages
    assert (bt[:, 1:] == -1).all()
    assert int(c.num_free()) == c.pool_pages - 2
    assert c.pool_pages == 2 * 4


def test_write_token_places_at_head():
    c = _cache()
    B, KV, hd = 2, 2, 8
    k = jnp.ones((B, KV, hd))
    v = 2 * jnp.ones((B, KV, hd))
    c = pc.write_token(c, k, v, jnp.array([0, 0]), jnp.array([1.0, 2.0]))
    assert int(c.cur_off[0]) == 1
    np.testing.assert_array_equal(np.asarray(c.pos_view()[:, 0, 0]), [0, 0])
    assert float(c.score_view()[1, 0, 0]) == 2.0
    assert int(c.total_valid()[0]) == 1


def test_write_token_respects_active_mask():
    c = _cache()
    k = jnp.ones((2, 2, 8))
    c = pc.write_token(c, k, k, jnp.array([5, 5]), jnp.zeros(2),
                       active=jnp.array([True, False]))
    assert int(c.total_valid()[0]) == 1
    assert int(c.total_valid()[1]) == 0
    assert int(c.cur_off[1]) == 0


def test_page_scores_mean_and_inf_for_empty():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.full((2,), float(i)))
    ps = np.asarray(c.page_scores())
    assert np.allclose(ps[:, 0], 1.5)              # mean(0,1,2,3)
    assert np.isinf(ps[:, 1:]).all()


def test_evict_page_returns_to_free_list():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
    free_before = int(c.num_free())
    c = pc.evict_page(c, jnp.array([0, 0]))
    assert int(c.total_valid()[0]) == 0
    assert int(c.num_free()) == free_before + 2    # both pages back in pool
    assert (np.asarray(c.block_table)[:, 0] == -1).all()
    # the freed physical pages hold no live tokens (invariant F4)
    ref = np.asarray(c.ref_count)
    assert (np.asarray(c.pos)[ref == 0] == -1).all()
    # and can be re-allocated
    c2, phys, ok = pc.alloc_pages(c, jnp.array([True, True]))
    assert bool(ok.all())
    assert len(set(np.asarray(phys).tolist())) == 2


def test_alloc_pages_distinct_and_bounded():
    c = _cache(B=3, P=2)                            # pool = 6, 3 pre-mapped
    c, phys, ok = pc.alloc_pages(c, jnp.array([True, False, True]))
    p = np.asarray(phys)
    assert bool(ok[0]) and not bool(ok[1]) and bool(ok[2])
    assert p[0] != p[2] and p[1] == c.pool_pages    # sentinel where not needed
    # exhaust the pool: only 1 free page left now
    c, phys2, ok2 = pc.alloc_pages(c, jnp.array([True, True, True]))
    assert int(np.asarray(ok2).sum()) == 1


def test_evict_token_flat_index():
    c = _cache()
    for i in range(6):                              # fills page0 + 2 of page1
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
        if int(c.cur_off[0]) == c.page_size:
            c2, phys, ok = pc.alloc_pages(c, jnp.ones((2,), bool))
            c = pc.start_new_page(c2, jnp.array([1, 1]), phys, ok)
    c = pc.evict_token(c, jnp.array([2, 5]))        # page0/off2 ; page1/off1
    pos = np.asarray(c.pos_view())
    assert pos[0, 0, 2] == -1 and pos[1, 1, 1] == -1
    assert int(c.total_valid()[0]) == 5


def test_reclaim_empty_pages():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.ones((2, 2, 8)), jnp.ones((2, 2, 8)),
                           jnp.full((2,), i), jnp.zeros(2))
    c2, phys, ok = pc.alloc_pages(c, jnp.ones((2,), bool))
    c = pc.start_new_page(c2, jnp.array([1, 1]), phys, ok)
    # token-evict page 0 empty, one token at a time (stays mapped)
    for j in range(4):
        c = pc.evict_token(c, jnp.array([j, j]))
    assert (np.asarray(c.block_table)[:, 0] >= 0).all()
    c = pc.reclaim_empty_pages(c)
    assert (np.asarray(c.block_table)[:, 0] == -1).all()
    ref = np.asarray(c.ref_count)
    mapped = np.asarray(c.block_table)
    assert int((ref > 0).sum()) == (mapped >= 0).sum()


def test_to_contiguous_roundtrip():
    c = _cache()
    for i in range(4):
        c = pc.write_token(c, jnp.full((2, 2, 8), float(i)),
                           jnp.full((2, 2, 8), float(i)),
                           jnp.full((2,), i), jnp.zeros(2))
    k, v, pos, mask = pc.to_contiguous(c)
    assert k.shape == (2, 16, 2, 8)
    assert int(mask.sum()) == 8
    got = sorted(np.asarray(pos[0])[np.asarray(mask[0])].tolist())
    assert got == [0, 1, 2, 3]


def test_write_prompt_pages_layout():
    c = _cache(P=4, page=4)
    C = 8
    k = jnp.arange(2 * C * 2 * 8, dtype=jnp.float32).reshape(2, C, 2, 8)
    pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (2, C))
    score = jnp.ones((2, C))
    c = pc.write_prompt_pages(c, k, k, pos, score)
    assert int(c.cur_page[0]) == 2 and int(c.cur_off[0]) == 0
    assert int(c.total_valid()[0]) == C
    pv = np.asarray(c.pos_view())
    np.testing.assert_array_equal(pv[0, 0], [0, 1, 2, 3])
    np.testing.assert_array_equal(pv[0, 1], [4, 5, 6, 7])
    assert np.isinf(np.asarray(c.page_scores())[0, 2:]).all()
    # the decode working page is mapped (so write_token has a target), and
    # block tables never share physical pages
    bt = np.asarray(c.block_table)
    assert (bt[:, :3] >= 0).all() and (bt[:, 3] == -1).all()
    mapped = bt[bt >= 0]
    assert len(mapped) == len(set(mapped.tolist()))


def test_insert_request_splices_row():
    B, P, page = 3, 3, 4
    dst = _cache(B=B, P=P, page=page)
    rng = jax.random.PRNGKey(0)
    for i in range(3):
        rng, k1 = jax.random.split(rng)
        dst = pc.write_token(dst, jax.random.normal(k1, (B, 2, 8)),
                             jnp.ones((B, 2, 8)), jnp.full((B,), i),
                             jnp.zeros(B))
    src = _cache(B=1, P=P, page=page)
    for i in range(2):
        rng, k1 = jax.random.split(rng)
        src = pc.write_token(src, jax.random.normal(k1, (1, 2, 8)),
                             jnp.ones((1, 2, 8)), jnp.full((1,), i),
                             jnp.zeros(1))
    out = pc.insert_request(dst, src, 1)
    np.testing.assert_array_equal(np.asarray(out.pos_view()[1]),
                                  np.asarray(src.pos_view()[0]))
    np.testing.assert_array_equal(np.asarray(out.pos_view()[0]),
                                  np.asarray(dst.pos_view()[0]))
    m = np.asarray(out.valid_mask()[1])[..., None, None]
    np.testing.assert_allclose(np.asarray(out.k_view()[1]) * m,
                               np.asarray(src.k_view()[0]) * m, atol=1e-6)
    # free-list conservation after the splice
    ref = np.asarray(out.ref_count)
    bt = np.asarray(out.block_table)
    mapped = bt[bt >= 0]
    assert len(mapped) == len(set(mapped.tolist()))
    assert int((ref > 0).sum()) + int(out.num_free()) == out.pool_pages
