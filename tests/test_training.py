"""Training substrate tests: optimizer math, loss descent, data, checkpoints."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.models import init_model
from repro.training import (
    AdamWConfig,
    DataConfig,
    adamw_update,
    cross_entropy,
    init_adamw,
    latest_step,
    lm_batch,
    load_checkpoint,
    lr_schedule,
    make_train_step,
    recall_batch,
    save_checkpoint,
)

pytestmark = pytest.mark.slow  # heavy tier: full suite only


def test_adamw_single_param_matches_reference():
    """Hand-check one AdamW step against the textbook update."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, grad_clip=1e9, beta1=0.9, beta2=0.99)
    st = init_adamw(p)
    p2, st2, m = adamw_update(p, g, st, cfg)
    mu = 0.1 * 0.5
    nu = 0.01 * 0.25
    upd = (mu / (1 - 0.9)) / (np.sqrt(nu / (1 - 0.99)) + cfg.eps)
    lr = float(lr_schedule(cfg, jnp.asarray(1)))
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p["w"]) - lr * upd, rtol=1e-5)
    assert int(st2.step) == 1


def test_weight_decay_skips_norms_and_biases():
    p = {"w_up": jnp.ones((2, 2)), "norm1": {"scale": jnp.ones((2,))}}
    g = jax.tree.map(jnp.zeros_like, p)
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, weight_decay=0.5,
                      total_steps=10)
    p2, _, _ = adamw_update(p, g, init_adamw(p), cfg)
    assert float(jnp.abs(p2["w_up"] - 1.0).max()) > 0      # decayed
    assert float(jnp.abs(p2["norm1"]["scale"] - 1.0).max()) == 0


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100,
                      lr_min_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, rel=1e-2)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, rel=1e-2)


def test_grad_clip_bounds_update():
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 1e6)}
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, grad_clip=1.0,
                      weight_decay=0.0, total_steps=10)
    _, _, m = adamw_update(p, g, init_adamw(p), cfg)
    assert float(m["grad_norm"]) > 1e6 - 1


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    targets = jnp.zeros((1, 4), jnp.int32)
    full = cross_entropy(logits, targets, jnp.ones((1, 4)))
    half = cross_entropy(logits, targets,
                         jnp.asarray([[1.0, 1.0, 0.0, 0.0]]))
    np.testing.assert_allclose(float(full), float(half), rtol=1e-6)
    np.testing.assert_allclose(float(full), np.log(8), rtol=1e-5)


def test_loss_descends_dense_and_moe():
    for arch in ("qwen2.5-3b", "mixtral-8x7b"):
        cfg = ASSIGNED_ARCHS[arch].reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt = init_adamw(params)
        step = jax.jit(make_train_step(
            cfg, AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=20)))
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4)
        losses = []
        for i in range(6):
            b = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()}
            params, opt, m = step(params, opt, b)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], f"{arch}: no descent {losses}"


def test_data_determinism_and_host_sharding():
    dcfg = DataConfig(vocab_size=128, seq_len=32, batch_size=2, seed=7)
    a = lm_batch(dcfg, 3)
    b = lm_batch(dcfg, 3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(dcfg, 3, host_id=1)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_recall_task_structure():
    dcfg = DataConfig(vocab_size=256, seq_len=64, batch_size=3, seed=1)
    b = recall_batch(dcfg, 0)
    assert b["tokens"].shape == (3, 64)
    assert (b["mask"].sum(axis=1) == 1).all()          # only the answer slot
    # the query token (2) appears near the end, key after it
    assert (b["tokens"][:, -2] == 2).all()
    v_lo = 3 + dcfg.key_space
    assert (b["answers"] >= v_lo).all()
    # the queried key's value is recoverable from the prompt
    for i in range(3):
        toks = b["tokens"][i]
        qkey = toks[-1]
        idx = np.where(toks[:-2] == qkey)[0]
        assert len(idx) >= 1
        assert toks[idx[0] + 1] == b["answers"][i]


def test_checkpoint_roundtrip_nested():
    cfg = ASSIGNED_ARCHS["xlstm-1.3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 3, {"params": params, "opt": opt})
        save_checkpoint(d, 7, {"params": params, "opt": opt})
        assert latest_step(d) == 7
        back = load_checkpoint(d, 7, {"params": params, "opt": opt})
        flat_a = jax.tree.leaves({"params": params, "opt": opt})
        flat_b = jax.tree.leaves(back)
        assert len(flat_a) == len(flat_b)
        for x, y in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
