"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design; only launch/dryrun.py requests 512 placeholder devices."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig


KERNEL_MODULES = {
    "test_kernels", "test_block_table_kernel", "test_chunked_prefill",
    "test_prefix_sharing", "test_kernel_perf",
}


def pytest_collection_modifyitems(config, items):
    """Everything not explicitly marked slow is the fast (CI) tier. Kernel
    parity suites additionally get the ``kernels`` marker (applied here by
    module name so the suites themselves stay byte-identical across kernel
    PRs — they are the fixed contract the kernels must keep passing)."""
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.fast)
        if item.module is not None and \
                item.module.__name__ in KERNEL_MODULES:
            item.add_marker(pytest.mark.kernels)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def small_ccfg():
    return CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")


def make_kv(key, B=2, S=40, KV=2, hd=16, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    k = jax.random.normal(k1, (B, S, KV, hd), dtype)
    v = jax.random.normal(k2, (B, S, KV, hd), dtype)
    return k, v
