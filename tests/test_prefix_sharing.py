"""Copy-on-write prefix sharing (DESIGN.md §7).

Allocator level: adopt/fork/unref semantics — ref_count as a true count,
the unmap-vs-free split, CoW forks before token mutation, clamped releases.
Scheduler level: the radix prefix index. Engine level: a second request with
a >= 50% shared prompt prefix prefills only the non-shared chunks, pool
occupancy drops vs. the no-sharing baseline, and outputs stay bit-identical
with sharing on or off (shared pages are immutable; eviction under sharing
never corrupts a sharer's view).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.core import (
    adopt_prefix,
    append_chunk,
    evict_page,
    evict_token,
    evict_token_mask,
    fork_page,
    get_policy,
    init_layer_cache,
    release_rows,
    row_intact_prefix_pages,
)
from repro.core import paged_cache as pc
from repro.models import init_model
from repro.models.attention import paged_attention_ref
from repro.serving import Engine
from repro.serving.scheduler import RadixPrefixIndex

from tests.test_pool_invariants import _assert_pool_invariants


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _filled_cache(B=2, P=3, page=4, KV=1, hd=8, rows=(0,), n_tokens=8,
                  seed=0, pool=None):
    """Cache where each row in ``rows`` holds ``n_tokens`` deterministic
    tokens written through the normal chunked-append path."""
    cache = init_layer_cache(B, P, page, KV, hd, jnp.float32, pool_pages=pool)
    rng = np.random.RandomState(seed)
    T = n_tokens
    k = jnp.asarray(rng.randn(B, T, KV, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, T, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    n_tok = jnp.asarray([T if b in rows else 0 for b in range(B)], jnp.int32)
    pos = jnp.where(jnp.arange(T)[None] < n_tok[:, None], pos, -1)
    score = jnp.asarray(rng.rand(B, T), jnp.float32)
    return append_chunk(cache, k, v, pos, score, n_tok)


def _adopt(cache, dst, src, n_pages):
    """Adopt row ``src``'s first ``n_pages`` into row ``dst``, mirroring the
    engine's call order: release the (re)starting row first — adopt_prefix
    requires an EMPTY destination row (init pre-maps each row's first page)."""
    B = cache.batch
    enable = jnp.asarray([b == dst for b in range(B)])
    cache = release_rows(cache, enable)
    return adopt_prefix(
        cache,
        jnp.full((B,), src, jnp.int32),
        jnp.full((B,), n_pages, jnp.int32),
        enable=enable)


def _dense_attn(q, k, v):
    """Plain softmax attention oracle. q: (hd,); k, v: (n, hd)."""
    s = (k @ q) / np.sqrt(q.shape[-1])
    w = np.exp(s - s.max())
    w = w / w.sum()
    return w @ v


def _row_dense_ref(cache, row, q, cur_pos):
    """Dense reference for row's single-token attention from the cache's
    own live tokens (KV == 1 head)."""
    pos = np.asarray(cache.pos_view()[row]).reshape(-1)
    kk = np.asarray(cache.k_view()[row]).reshape(len(pos), -1)
    vv = np.asarray(cache.v_view()[row]).reshape(len(pos), -1)
    live = (pos >= 0) & (pos <= cur_pos)
    return _dense_attn(np.asarray(q), kk[live], vv[live])


# ---------------------------------------------------------------------------
# satellite: ref_count clamping / free refusal
# ---------------------------------------------------------------------------

def test_unref_clamps_and_free_refuses_shared():
    cache = _filled_cache(rows=(0,), n_tokens=8)          # row 0: 2 full pages
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    _assert_pool_invariants(cache, "after adopt")
    phys = np.asarray(cache.block_table)[0, :2]
    assert (np.asarray(cache.ref_count)[phys] == 2).all()

    # releasing one mapper must NOT recycle the page: data stays live
    before_pos = np.asarray(cache.pos)[phys]
    cache2 = release_rows(cache, jnp.asarray([True, False]))
    assert (np.asarray(cache2.ref_count)[phys] == 1).all()
    np.testing.assert_array_equal(np.asarray(cache2.pos)[phys], before_pos)

    # double-release the SAME physical page in one batched op: the scatter
    # counts both, but the count clamps at 0 instead of underflowing
    tgt = jnp.asarray([int(phys[0])] * 4)
    cache3 = pc._unref_pages(cache2, tgt)
    ref3 = np.asarray(cache3.ref_count)
    assert (ref3 >= 0).all()
    assert ref3[phys[0]] == 0
    assert (np.asarray(cache3.pos)[phys[0]] == -1).all()  # freed -> emptied

    # _free_phys on a still-shared page only decrements (refuses to recycle)
    cache4 = pc._free_phys(cache, jnp.full((2,), int(phys[0]), jnp.int32),
                           jnp.asarray([True, False]))
    assert np.asarray(cache4.ref_count)[phys[0]] == 1
    assert (np.asarray(cache4.pos)[phys[0]] >= 0).all()


# ---------------------------------------------------------------------------
# tentpole: CoW fork
# ---------------------------------------------------------------------------

def test_fork_page_gives_private_copy_and_sharer_view_is_bit_exact():
    cache = _filled_cache(rows=(0,), n_tokens=8)
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    src_k = np.asarray(cache.k).copy()
    src_pos = np.asarray(cache.pos).copy()
    phys0 = int(np.asarray(cache.block_table)[0, 0])

    cache, forked = fork_page(cache, jnp.zeros((2,), jnp.int32),
                              enable=jnp.asarray([False, True]))
    forked = np.asarray(forked)
    assert forked[1] and not forked[0]
    bt = np.asarray(cache.block_table)
    newp = int(bt[1, 0])
    assert newp != phys0, "fork must remap to a fresh physical page"
    assert int(bt[0, 0]) == phys0, "source mapping untouched"
    ref = np.asarray(cache.ref_count)
    assert ref[phys0] == 1 and ref[newp] == 1
    # the copy is bit-exact at fork time
    np.testing.assert_array_equal(np.asarray(cache.k)[newp], src_k[phys0])
    np.testing.assert_array_equal(np.asarray(cache.pos)[newp], src_pos[phys0])
    _assert_pool_invariants(cache, "after fork")

    # the mutating request diverges; the sharer's view stays bit-exact
    cache = evict_token(cache, jnp.asarray([0, 1], jnp.int32),
                        enable=jnp.asarray([False, True]))
    assert np.asarray(cache.pos)[newp, 1] == -1
    np.testing.assert_array_equal(np.asarray(cache.pos)[phys0], src_pos[phys0])
    np.testing.assert_array_equal(np.asarray(cache.k)[phys0], src_k[phys0])


def test_evict_token_on_shared_page_forks_automatically():
    cache = _filled_cache(rows=(0,), n_tokens=8)
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    phys0 = int(np.asarray(cache.block_table)[0, 0])
    pos_before = np.asarray(cache.pos)[phys0].copy()

    # row 1 evicts flat token 2 (page 0, offset 2) — a shared page
    cache = evict_token(cache, jnp.full((2,), 2, jnp.int32),
                        enable=jnp.asarray([False, True]))
    bt = np.asarray(cache.block_table)
    assert bt[1, 0] != phys0, "CoW fork must have remapped row 1"
    np.testing.assert_array_equal(np.asarray(cache.pos)[phys0], pos_before)
    assert np.asarray(cache.pos)[bt[1, 0], 2] == -1
    _assert_pool_invariants(cache, "after auto-fork evict")


def test_evict_token_mask_forks_lazily_and_never_corrupts():
    cache = _filled_cache(rows=(0,), n_tokens=8)
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    phys = np.asarray(cache.block_table)[0, :2].copy()
    pos_before = np.asarray(cache.pos)[phys].copy()

    # row 1 targets tokens on BOTH shared pages at once: one page forks per
    # call (lazy CoW); un-forked shared targets are skipped, NEVER mutated
    B, P, page = 2, cache.num_pages, cache.page_size
    mask = np.zeros((B, P, page), bool)
    mask[1, 0, 1] = mask[1, 1, 1] = True
    cache = evict_token_mask(cache, jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(cache.pos)[phys], pos_before)
    _assert_pool_invariants(cache, "after first masked evict")
    # second call forks the remaining page; both rows fully diverged
    cache = evict_token_mask(cache, jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(cache.pos)[phys], pos_before)
    bt = np.asarray(cache.block_table)
    assert bt[1, 0] not in phys and bt[1, 1] not in phys
    assert np.asarray(cache.pos)[bt[1, 0], 1] == -1
    assert np.asarray(cache.pos)[bt[1, 1], 1] == -1
    _assert_pool_invariants(cache, "after second masked evict")


def test_fork_starvation_skips_mutation_not_corrupts():
    # pool: 3 pages, all in use after row 1 rolls its own page -> a fork
    # cannot allocate
    cache = _filled_cache(B=2, P=2, page=4, rows=(0,), n_tokens=8, pool=3)
    cache = _adopt(cache, dst=1, src=0, n_pages=1)
    rng = np.random.RandomState(1)
    k = jnp.asarray(rng.randn(2, 4, 1, 8), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4, 8, dtype=jnp.int32), (2, 4))
    n_tok = jnp.asarray([0, 4], jnp.int32)
    pos = jnp.where(jnp.arange(4)[None] < n_tok[:, None], pos, -1)
    cache = append_chunk(cache, k, k, pos, jnp.zeros((2, 4)), n_tok)
    assert int(cache.num_free()) == 0

    shared = int(np.asarray(cache.block_table)[1, 0])
    pos_before = np.asarray(cache.pos)[shared].copy()
    cache = evict_token(cache, jnp.full((2,), 1, jnp.int32),
                        enable=jnp.asarray([False, True]))
    # no free page -> no fork -> the shared page must be left untouched
    np.testing.assert_array_equal(np.asarray(cache.pos)[shared], pos_before)
    assert np.asarray(cache.ref_count)[shared] == 2
    _assert_pool_invariants(cache, "after starved fork")


# ---------------------------------------------------------------------------
# tentpole: eviction under sharing never changes the sharer's attention
# ---------------------------------------------------------------------------

def test_page_eviction_on_shared_page_is_unmap_only():
    cache = _filled_cache(rows=(0,), n_tokens=8)
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(2, 1, 8), jnp.float32)   # (B, H=1, hd)
    cur = jnp.full((2,), 7, jnp.int32)
    out_before = np.asarray(paged_attention_ref(q, cache, cur_pos=cur))

    # row 0 prunes shared page 0 (paper Alg.2/3 path)
    cache = evict_page(cache, jnp.zeros((2,), jnp.int32),
                       enable=jnp.asarray([True, False]))
    _assert_pool_invariants(cache, "after shared-page evict")
    out_after = np.asarray(paged_attention_ref(q, cache, cur_pos=cur))
    # the sharer's attention output is bit-exact
    np.testing.assert_array_equal(out_after[1], out_before[1])
    # and matches a dense reference over its live tokens
    np.testing.assert_allclose(
        out_after[1, 0], _row_dense_ref(cache, 1, np.asarray(q)[1, 0], 7),
        rtol=1e-5)
    # the evicting row really lost the page
    assert np.asarray(cache.block_table)[0, 0] == -1
    assert int(np.asarray(cache.ref_count)[
        np.asarray(cache.block_table)[1, 0]]) == 1


def test_three_way_sharing_mixed_eviction():
    cache = _filled_cache(B=3, P=3, rows=(0,), n_tokens=8)
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    cache = _adopt(cache, dst=2, src=0, n_pages=2)
    phys = np.asarray(cache.block_table)[0, :2]
    assert (np.asarray(cache.ref_count)[phys] == 3).all()
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(3, 1, 8), jnp.float32)
    cur = jnp.full((3,), 7, jnp.int32)
    base = np.asarray(paged_attention_ref(q, cache, cur_pos=cur))

    # row 0 unmaps page 0; row 1 CoW-mutates a token on page 1; row 2 idle
    cache = evict_page(cache, jnp.zeros((3,), jnp.int32),
                       enable=jnp.asarray([True, False, False]))
    cache = evict_token(cache, jnp.full((3,), 5, jnp.int32),
                        enable=jnp.asarray([False, True, False]))
    _assert_pool_invariants(cache, "after mixed eviction")
    out = np.asarray(paged_attention_ref(q, cache, cur_pos=cur))
    np.testing.assert_array_equal(out[2], base[2])      # untouched sharer


def test_adopt_prefix_probe_and_write_head():
    cache = _filled_cache(B=2, P=3, rows=(0,), n_tokens=10)  # 2 full + 1 part
    # only COMPLETE position-contiguous pages count, capped at P-1
    assert int(row_intact_prefix_pages(cache, 0)) == 2
    assert int(row_intact_prefix_pages(cache, 1)) == 0
    cache = _adopt(cache, dst=1, src=0, n_pages=2)
    # head parks FULL on the last adopted slot: first append rolls fresh
    assert int(np.asarray(cache.cur_page)[1]) == 1
    assert int(np.asarray(cache.cur_off)[1]) == cache.page_size
    # punch a hole in row 0's page 0 -> its intact prefix collapses
    holed = evict_token(cache, jnp.full((2,), 1, jnp.int32),
                        enable=jnp.asarray([True, False]))
    assert int(row_intact_prefix_pages(holed, 0)) == 0
    # ... but row 1 (forked away by CoW? no — row 0 mutated, so IT forked)
    _assert_pool_invariants(holed, "after hole")


# ---------------------------------------------------------------------------
# scheduler: radix prefix index
# ---------------------------------------------------------------------------

def test_radix_index_longest_match_and_remove():
    idx = RadixPrefixIndex(page_size=4)
    a = np.arange(12, dtype=np.int32)                 # pages [0..3][4..7][8..11]
    b = np.concatenate([np.arange(8), [99, 98, 97, 96]]).astype(np.int32)
    idx.insert(0, a)
    idx.insert(1, b)
    src, n = idx.lookup(np.arange(12, dtype=np.int32))
    assert (src, n) == (0, 3)
    src, n = idx.lookup(b)
    assert (src, n) == (1, 3)
    # 2-page common prefix matches both; lowest slot wins
    src, n = idx.lookup(np.concatenate([np.arange(8), [5, 5, 5, 5]])
                        .astype(np.int32))
    assert (src, n) == (0, 2)
    # exclusion re-routes to the other resident
    src, n = idx.lookup(np.arange(12, dtype=np.int32), exclude={0})
    assert (src, n) == (1, 2)
    # partial pages never participate
    src, n = idx.lookup(np.arange(3, dtype=np.int32))
    assert (src, n) == (-1, 0)
    # removal prunes: no stale match survives
    idx.remove(0)
    idx.remove(1)
    assert idx.lookup(a) == (-1, 0)
    assert not idx.root.children


def test_radix_index_no_hash_collisions_across_dtypes_values():
    idx = RadixPrefixIndex(page_size=2)
    idx.insert(0, np.asarray([1, 2, 3, 4], np.int32))
    # same bytes length, different values -> distinct edges
    assert idx.lookup(np.asarray([1, 2, 9, 9], np.int32)) == (0, 1)
    assert idx.lookup(np.asarray([2, 1, 3, 4], np.int32)) == (-1, 0)


# ---------------------------------------------------------------------------
# engine: end-to-end shared-prefix admission (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shared_setup():
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prefix = rng.randint(0, cfg.vocab_size, size=40)   # 5 full pages of 8
    prompts = [np.concatenate([prefix,
                               rng.randint(0, cfg.vocab_size, size=16)])
               .astype(np.int32) for _ in range(3)]
    return cfg, params, prompts


def _run_engine(cfg, params, prompts, *, sharing, policy="paged_eviction",
                budget=64, max_new=8):
    ccfg = CacheConfig(page_size=8, cache_budget=budget, policy=policy,
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=4, max_prompt_len=64,
                 max_new_tokens=max_new, chunk_size=16, prefix_sharing=sharing)
    for p in prompts:
        eng.submit(p)
    peak = 0
    steps = 0
    while eng.step() and steps < 400:
        steps += 1
        ps = eng.pool_stats()
        peak = max(peak, ps["pool_pages"] - ps["free_pages"])
        for lc in list(eng.cache.pattern) + list(eng.cache.tail):
            if lc.kv is None:
                continue
            kv = lc.kv
            n_layers = kv.ref_count.shape[0] if kv.ref_count.ndim == 2 else 1
            for r in range(n_layers):
                one = jax.tree.map(lambda a: a[r], kv) \
                    if kv.ref_count.ndim == 2 else kv
                _assert_pool_invariants(one, f"step {steps} rep {r}")
    outs = {r.request_id: list(r.output_tokens)
            for r in eng.scheduler.finished}
    return eng, outs, peak


def test_engine_shared_prefix_skips_prefill_and_saves_pages(shared_setup):
    cfg, params, prompts = shared_setup
    eng_s, outs_s, peak_s = _run_engine(cfg, params, prompts, sharing=True)
    eng_n, outs_n, peak_n = _run_engine(cfg, params, prompts, sharing=False)

    # 2 of 3 requests adopt the 40-token prefix (>= 50% of the 56-token
    # prompt): their prefill runs only the non-shared chunks
    assert eng_s.stats.shared_prefix_hits == 2
    assert eng_s.stats.shared_prefix_tokens == 80
    for r in eng_s.scheduler.finished:
        if r.share_src >= 0:
            assert r.shared_tokens == 40
    assert eng_n.stats.shared_prefix_hits == 0

    # pool pages in use drop vs. the no-sharing baseline
    assert peak_s < peak_n, (peak_s, peak_n)

    # outputs are bit-identical — shared pages are immutable and eviction
    # under sharing never leaks across requests
    assert outs_s == outs_n


def test_engine_sharing_with_token_eviction_policy(shared_setup):
    """streaming_llm evicts tokens every decode step — under sharing those
    hits land on shared prefix pages and must CoW-fork, never corrupt."""
    cfg, params, prompts = shared_setup
    eng_s, outs_s, _ = _run_engine(cfg, params, prompts, sharing=True,
                                   policy="streaming_llm", budget=64,
                                   max_new=12)
    eng_n, outs_n, _ = _run_engine(cfg, params, prompts, sharing=False,
                                   policy="streaming_llm", budget=64,
                                   max_new=12)
    assert outs_s == outs_n
    assert eng_s.stats.shared_prefix_hits >= 1


def test_engine_prefix_sharing_flag():
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32,
                       policy="paged_eviction", dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=2, max_prompt_len=32,
                 max_new_tokens=4, prefix_sharing=False)
    assert eng.scheduler.prefix_index is None
    eng2 = Engine(cfg, params, cache_cfg=ccfg, max_batch=2, max_prompt_len=32,
                  max_new_tokens=4)
    assert eng2.scheduler.prefix_index is not None
