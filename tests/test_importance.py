"""Unit tests for the paper's importance proxies (Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import importance


def test_vk_ratio_matches_manual():
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (3, 7, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 1), (3, 7, 2, 16))
    s = importance.vk_ratio_score(k, v)
    kn = jnp.mean(jnp.linalg.norm(k, axis=-1), axis=-1)
    vn = jnp.mean(jnp.linalg.norm(v, axis=-1), axis=-1)
    np.testing.assert_allclose(np.asarray(s), np.asarray(vn / kn), rtol=1e-5)


def test_vk_ratio_monotone_in_value_norm():
    """Scaling V up must increase importance; scaling K up must decrease."""
    key = jax.random.PRNGKey(1)
    k = jax.random.normal(key, (4, 10, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (4, 10, 2, 8))
    base = importance.vk_ratio_score(k, v)
    assert bool(jnp.all(importance.vk_ratio_score(k, 2.0 * v) > base))
    assert bool(jnp.all(importance.vk_ratio_score(2.0 * k, v) < base))


def test_inverse_key_l2_prefers_low_norm():
    k = jnp.stack([jnp.ones((1, 2, 8)), 3.0 * jnp.ones((1, 2, 8))], axis=1)
    s = importance.inverse_key_l2_score(k)          # (1, 2)
    assert float(s[0, 0]) > float(s[0, 1])


def test_keydiff_penalizes_mean_aligned_keys():
    mean = jnp.ones((1, 1, 1, 8))
    aligned = jnp.ones((1, 1, 1, 8))
    ortho = jnp.concatenate([jnp.ones((1, 1, 1, 4)), -jnp.ones((1, 1, 1, 4))],
                            axis=-1)
    k = jnp.concatenate([aligned, ortho], axis=1)   # (1, 2, 1, 8)
    s = importance.keydiff_score(k, mean)
    assert float(s[0, 0]) < float(s[0, 1])


def test_block_scores_mean_and_empty():
    ts = jnp.asarray([[1.0, 3.0, 5.0, 7.0]])
    valid = jnp.asarray([[True, True, False, False]])
    bs = importance.block_scores_from_token_scores(ts, valid, page_size=2)
    assert float(bs[0, 0]) == 2.0
    assert np.isinf(np.asarray(bs)[0, 1])


def test_scores_finite_on_degenerate_inputs():
    z = jnp.zeros((2, 5, 2, 8))
    assert bool(jnp.isfinite(importance.vk_ratio_score(z, z)).all())
    assert bool(jnp.isfinite(importance.keydiff_score(z, z)).all())
