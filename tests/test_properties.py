"""Property tests on the system's core invariants.

``hypothesis`` is not installed in the offline CI container, so every
property is written as a plain check function and driven two ways:

  * when hypothesis IS available, @given explores the parameter space;
  * otherwise a seeded ``jax.random`` fallback sweeps a fixed set of draws,
    so the invariants still execute everywhere (pytest.importorskip guards
    the hypothesis-only entry points).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache
from repro.core import importance

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # offline container: seeded fallback below
    HAVE_HYPOTHESIS = False

_POLICIES = ["paged_eviction", "streaming_llm", "inverse_key_l2", "keydiff",
             "full"]
_SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# property bodies (engine-agnostic: called by hypothesis AND the fallback)
# ---------------------------------------------------------------------------

def check_cache_invariants_under_any_decode_trace(page, budget_pages, steps,
                                                  policy, seed):
    """For ANY policy and ANY random decode trace:
    I1 live tokens never exceed budget + page (working page transient)
    I2 positions live in the cache are unique
    I3 the write head always points at a non-full page slot
    I4 cur_off in [0, page)
    I5 full policy: nothing is ever evicted
    F1 allocated + free == N_pool (free-list conservation)
    F3 no physical page mapped twice
    """
    budget = budget_pages * page
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    pages = pol.slab_pages(cfg, max(steps, budget + page))
    B = 2
    cache = init_layer_cache(B, pages, page, 1, 4, jnp.float32)
    rng = jax.random.PRNGKey(seed)
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache,
                            jax.random.normal(k1, (B, 1, 4)),
                            jax.random.normal(k2, (B, 1, 4)),
                            jnp.full((B,), t), pol, cfg)
        cache = out.cache
        tv = np.asarray(cache.total_valid())
        if policy == "full":
            assert (tv == t + 1).all()
        else:
            assert (tv <= budget + page).all(), (policy, t, tv)
        pos = np.asarray(cache.pos_view())
        for b in range(B):
            live = pos[b][pos[b] >= 0]
            assert len(live) == len(set(live.tolist())), "duplicate positions"
        off = np.asarray(cache.cur_off)
        assert ((off >= 0) & (off < page)).all()
        tpp = np.asarray(cache.tokens_per_page())
        cur = np.asarray(cache.cur_page)
        for b in range(B):
            assert tpp[b, cur[b]] <= page
        ref = np.asarray(cache.ref_count)
        bt = np.asarray(cache.block_table)
        mapped = bt[bt >= 0]
        assert len(mapped) == len(set(mapped.tolist())), "double-mapped page"
        assert int((ref > 0).sum()) + int((ref == 0).sum()) == cache.pool_pages
        assert int((ref > 0).sum()) == len(mapped), "free-list conservation"


def check_importance_scale_invariances(shape, seed, scale):
    """||V||/||K|| is homogeneous: scaling V by a scales score by a; scaling
    K by a scales it by 1/a; keydiff is scale-invariant in both args."""
    key = jax.random.PRNGKey(seed)
    k = jax.random.normal(key, shape) + 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), shape) + 0.1
    s = np.asarray(importance.vk_ratio_score(k, v))
    np.testing.assert_allclose(
        np.asarray(importance.vk_ratio_score(k, scale * v)), scale * s,
        rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(importance.vk_ratio_score(scale * k, v)), s / scale,
        rtol=1e-4)
    mean = jnp.mean(k, axis=-3, keepdims=True)
    kd = np.asarray(importance.keydiff_score(k, mean))
    kd2 = np.asarray(importance.keydiff_score(scale * k, mean))
    np.testing.assert_allclose(kd, kd2, rtol=1e-4, atol=1e-5)


def check_prefill_keeps_exactly_topk_by_score(S, budget, policy, seed):
    """Alg.2: the retained set == top-budget tokens by the policy's score."""
    from repro.core.prefill import compress_and_page
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=8, cache_budget=budget, policy=policy,
                      dtype="float32")
    key = jax.random.PRNGKey(seed)
    k = jax.random.normal(key, (1, S, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 8))
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    cache = compress_and_page(k, v, positions, jnp.ones((1, S), bool), pol, cfg)
    live = np.asarray(cache.pos_view()[0]).ravel()
    live = set(live[live >= 0].tolist())
    scores = np.asarray(pol.prefill_scores(k, v, positions))[0]
    expected = set(np.argsort(-scores, kind="stable")[:budget].tolist())
    # ties could differ; compare scores not indices when collisions exist
    if len(set(scores.tolist())) == S:
        assert live == expected


def check_paged_attention_permutation_invariance(B, T, seed):
    """Attention over the pooled cache must not depend on WHICH physical
    page holds which tokens (block-table indirection is semantics-free)."""
    from repro.kernels.ref import paged_attention_block_table_ref
    key = jax.random.PRNGKey(seed)
    KV, G, hd, P, page = 2, 2, 16, 4, 8
    N = B * P + 2
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kp = jax.random.normal(ks[1], (KV, N, page, hd))
    vp = jax.random.normal(ks[2], (KV, N, page, hd))
    pos = jax.random.randint(ks[3], (N, page), -1, T + 1)
    bt = jax.random.permutation(ks[4], N)[:B * P].reshape(B, P).astype(jnp.int32)
    cur = jnp.full((B,), T, jnp.int32)
    base = paged_attention_block_table_ref(q, kp, vp, pos, bt, cur)
    # re-home every mapped page to a different physical slot
    perm = jnp.roll(jnp.arange(N), 1)
    kp2 = kp[:, jnp.argsort(perm)]
    vp2 = vp[:, jnp.argsort(perm)]
    pos2 = pos[jnp.argsort(perm)]
    bt2 = jnp.where(bt >= 0, perm[jnp.maximum(bt, 0)], -1)
    out = paged_attention_block_table_ref(q, kp2, vp2, pos2, bt2, cur)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-5)


def check_paged_eviction_page_uniformity(seed, steps):
    """The paper's structural claim as a property: under PagedEviction every
    non-working page is always exactly full or exactly empty."""
    pol = get_policy("paged_eviction")
    cfg = CacheConfig(page_size=4, cache_budget=8, policy="paged_eviction",
                      dtype="float32")
    cache = init_layer_cache(1, pol.slab_pages(cfg, steps + 8), 4, 1, 4,
                             jnp.float32)
    rng = jax.random.PRNGKey(seed)
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache, jax.random.normal(k1, (1, 1, 4)),
                            jax.random.normal(k2, (1, 1, 4)),
                            jnp.full((1,), t), pol, cfg)
        cache = out.cache
        tpp = np.asarray(cache.tokens_per_page())[0]
        cur = int(cache.cur_page[0])
        for p_i, n in enumerate(tpp):
            if p_i != cur:
                assert n in (0, cfg.page_size)


# ---------------------------------------------------------------------------
# hypothesis drivers (skipped when the package is unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(page=st.sampled_from([2, 4, 8]), budget_pages=st.integers(2, 4),
           steps=st.integers(1, 40), policy=st.sampled_from(_POLICIES),
           seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_cache_invariants_under_any_decode_trace(page, budget_pages,
                                                     steps, policy, seed):
        check_cache_invariants_under_any_decode_trace(page, budget_pages,
                                                      steps, policy, seed)

    @given(shape=st.sampled_from([(1, 5, 1, 4), (2, 9, 2, 8), (3, 4, 4, 16)]),
           seed=st.integers(0, 2**16), scale=st.floats(0.1, 10.0))
    @settings(**_SETTINGS)
    def test_importance_scale_invariances(shape, seed, scale):
        check_importance_scale_invariances(shape, seed, scale)

    @given(S=st.sampled_from([16, 24, 32]), budget=st.sampled_from([8, 16]),
           policy=st.sampled_from(["paged_eviction", "inverse_key_l2",
                                   "keydiff"]),
           seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_prefill_keeps_exactly_topk_by_score(S, budget, policy, seed):
        check_prefill_keeps_exactly_topk_by_score(S, budget, policy, seed)

    @given(B=st.integers(1, 3), T=st.integers(1, 20),
           seed=st.integers(0, 2**16))
    @settings(**_SETTINGS)
    def test_paged_attention_permutation_invariance(B, T, seed):
        check_paged_attention_permutation_invariance(B, T, seed)

    @given(seed=st.integers(0, 2**16), steps=st.integers(5, 30))
    @settings(**_SETTINGS)
    def test_paged_eviction_page_uniformity(seed, steps):
        check_paged_eviction_page_uniformity(seed, steps)
else:
    def test_hypothesis_available():
        """Records the skip visibly; the seeded fallbacks below still run."""
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# seeded jax.random fallback (always runs; deterministic draws)
# ---------------------------------------------------------------------------

def _draws(seed, n, *ranges):
    """n deterministic tuples, each element uniform over its (lo, hi]."""
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        vals = []
        for j, (lo, hi) in enumerate(ranges):
            k = jax.random.fold_in(key, i * len(ranges) + j)
            vals.append(int(jax.random.randint(k, (), lo, hi)))
        out.append(tuple(vals))
    return out


@pytest.mark.parametrize("policy", _POLICIES)
@pytest.mark.parametrize("draw", range(3))
def test_fallback_cache_invariants(policy, draw):
    page, budget_pages, steps, seed = _draws(
        draw * 31 + 7, 1, (1, 4), (2, 5), (1, 41), (0, 2**16))[0]
    check_cache_invariants_under_any_decode_trace(2 ** page, budget_pages,
                                                  steps, policy, seed)


@pytest.mark.parametrize("shape", [(1, 5, 1, 4), (2, 9, 2, 8), (3, 4, 4, 16)])
def test_fallback_importance_scale_invariances(shape):
    for i, seed in enumerate(_draws(11, 3, (0, 2**16))):
        check_importance_scale_invariances(shape, seed[0], 0.1 + 1.7 * i)


@pytest.mark.parametrize("policy", ["paged_eviction", "inverse_key_l2",
                                    "keydiff"])
def test_fallback_prefill_topk(policy):
    for (S, budget_i, seed) in _draws(13, 3, (16, 33), (0, 2), (0, 2**16)):
        check_prefill_keeps_exactly_topk_by_score(S - S % 8, [8, 16][budget_i],
                                                  policy, seed)


def test_fallback_permutation_invariance():
    for (B, T, seed) in _draws(17, 5, (1, 4), (1, 21), (0, 2**16)):
        check_paged_attention_permutation_invariance(B, T, seed)


def test_fallback_page_uniformity():
    for (seed, steps) in _draws(19, 4, (0, 2**16), (5, 31)):
        check_paged_eviction_page_uniformity(seed, steps)
