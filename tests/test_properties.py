"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache
from repro.core import importance

_SETTINGS = dict(max_examples=25, deadline=None)


@given(
    page=st.sampled_from([2, 4, 8]),
    budget_pages=st.integers(2, 4),
    steps=st.integers(1, 40),
    policy=st.sampled_from(["paged_eviction", "streaming_llm",
                            "inverse_key_l2", "keydiff", "full"]),
    seed=st.integers(0, 2**16),
)
@settings(**_SETTINGS)
def test_cache_invariants_under_any_decode_trace(page, budget_pages, steps,
                                                 policy, seed):
    """For ANY policy and ANY random decode trace:
    I1 live tokens never exceed budget + page (working page transient)
    I2 positions live in the cache are unique
    I3 the write head always points at a non-full page slot
    I4 cur_off in [0, page)
    I5 full policy: nothing is ever evicted
    """
    budget = budget_pages * page
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    pages = pol.slab_pages(cfg, max(steps, budget + page))
    B = 2
    cache = init_layer_cache(B, pages, page, 1, 4, jnp.float32)
    rng = jax.random.PRNGKey(seed)
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache,
                            jax.random.normal(k1, (B, 1, 4)),
                            jax.random.normal(k2, (B, 1, 4)),
                            jnp.full((B,), t), pol, cfg)
        cache = out.cache
        tv = np.asarray(cache.total_valid())
        if policy == "full":
            assert (tv == t + 1).all()
        else:
            assert (tv <= budget + page).all(), (policy, t, tv)
        pos = np.asarray(cache.pos)
        for b in range(B):
            live = pos[b][pos[b] >= 0]
            assert len(live) == len(set(live.tolist())), "duplicate positions"
        off = np.asarray(cache.cur_off)
        assert ((off >= 0) & (off < page)).all()
        tpp = np.asarray(cache.tokens_per_page())
        cur = np.asarray(cache.cur_page)
        for b in range(B):
            assert tpp[b, cur[b]] <= page


@given(
    shape=st.sampled_from([(1, 5, 1, 4), (2, 9, 2, 8), (3, 4, 4, 16)]),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.1, 10.0),
)
@settings(**_SETTINGS)
def test_importance_scale_invariances(shape, seed, scale):
    """||V||/||K|| is homogeneous: scaling V by a scales score by a; scaling
    K by a scales it by 1/a; keydiff is scale-invariant in both args."""
    key = jax.random.PRNGKey(seed)
    k = jax.random.normal(key, shape) + 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), shape) + 0.1
    s = np.asarray(importance.vk_ratio_score(k, v))
    np.testing.assert_allclose(
        np.asarray(importance.vk_ratio_score(k, scale * v)), scale * s,
        rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(importance.vk_ratio_score(scale * k, v)), s / scale,
        rtol=1e-4)
    mean = jnp.mean(k, axis=-3, keepdims=True)
    kd = np.asarray(importance.keydiff_score(k, mean))
    kd2 = np.asarray(importance.keydiff_score(scale * k, mean))
    np.testing.assert_allclose(kd, kd2, rtol=1e-4, atol=1e-5)


@given(
    S=st.sampled_from([16, 24, 32]),
    budget=st.sampled_from([8, 16]),
    policy=st.sampled_from(["paged_eviction", "inverse_key_l2", "keydiff"]),
    seed=st.integers(0, 2**16),
)
@settings(**_SETTINGS)
def test_prefill_keeps_exactly_topk_by_score(S, budget, policy, seed):
    """Alg.2: the retained set == top-budget tokens by the policy's score."""
    from repro.core.prefill import compress_and_page
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=8, cache_budget=budget, policy=policy,
                      dtype="float32")
    key = jax.random.PRNGKey(seed)
    k = jax.random.normal(key, (1, S, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 8))
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    cache = compress_and_page(k, v, positions, jnp.ones((1, S), bool), pol, cfg)
    live = np.asarray(cache.pos[0]).ravel()
    live = set(live[live >= 0].tolist())
    scores = np.asarray(pol.prefill_scores(k, v, positions))[0]
    expected = set(np.argsort(-scores, kind="stable")[:budget].tolist())
    # ties could differ; compare scores not indices when collisions exist
    if len(set(scores.tolist())) == S:
        assert live == expected


@given(
    B=st.integers(1, 3),
    T=st.integers(1, 20),
    seed=st.integers(0, 2**16),
)
@settings(**_SETTINGS)
def test_paged_attention_permutation_invariance(B, T, seed):
    """Attention over the paged cache must not depend on WHICH physical page
    holds which tokens (block-table indirection is semantics-free)."""
    from repro.kernels.ref import paged_attention_ref
    key = jax.random.PRNGKey(seed)
    KV, G, hd, P, page = 2, 2, 16, 4, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kp = jax.random.normal(ks[1], (B, KV, P, page, hd))
    vp = jax.random.normal(ks[2], (B, KV, P, page, hd))
    pos = jnp.broadcast_to(
        jnp.arange(P * page, dtype=jnp.int32).reshape(P, page), (B, P, page))
    pos = jnp.where(pos < T, pos, -1)
    cur = jnp.full((B,), T, jnp.int32)
    base = paged_attention_ref(q, kp, vp, pos, cur)
    perm = jax.random.permutation(ks[3], P)
    out = paged_attention_ref(q, kp[:, :, perm], vp[:, :, perm],
                              pos[:, perm], cur)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-5)


@given(seed=st.integers(0, 2**16), steps=st.integers(5, 30))
@settings(**_SETTINGS)
def test_paged_eviction_page_uniformity(seed, steps):
    """The paper's structural claim as a property: under PagedEviction every
    non-working page is always exactly full or exactly empty."""
    pol = get_policy("paged_eviction")
    cfg = CacheConfig(page_size=4, cache_budget=8, policy="paged_eviction",
                      dtype="float32")
    cache = init_layer_cache(1, pol.slab_pages(cfg, steps + 8), 4, 1, 4,
                             jnp.float32)
    rng = jax.random.PRNGKey(seed)
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache, jax.random.normal(k1, (1, 1, 4)),
                            jax.random.normal(k2, (1, 1, 4)),
                            jnp.full((1,), t), pol, cfg)
        cache = out.cache
        tpp = np.asarray(cache.tokens_per_page())[0]
        cur = int(cache.cur_page[0])
        for p_i, n in enumerate(tpp):
            if p_i != cur:
                assert n in (0, cfg.page_size)
