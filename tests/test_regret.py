"""Eviction-regret shadow probes (repro.obs.regret; DESIGN.md §10).

The acceptance gates from the forensics PR:

- ``paged_eviction`` under budget pressure shows NONZERO regret — per-layer
  output divergence and shadow attention mass on evicted positions;
- a ``full``-cache engine probes to ~zero on both (the shadow recompute is
  the same attention math in f32);
- probes OFF is python-static: the engine's outputs are bit-identical with
  ``regret_every == 0`` vs any other obs configuration, and probes ON never
  perturb the sampled tokens either (taps are read-only);
- the probe records land on the v2 trace stream and per-request summaries
  aggregate them.

Plus unit coverage of the shadow-state lifecycle (reset / adopt / scatter
writes) and the numpy GQA reference used for the counterfactual.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.models import init_model
from repro.obs import ObsConfig
from repro.obs.regret import (REGRET_BOUNDS, ShadowState, _full_attention,
                              probe_record, regret_smoke, run_probe,
                              summarize_request)
from repro.obs.trace import validate_event
from repro.serving import Engine, SamplingParams


# ---------------------------------------------------------------------------
# shadow state + numpy attention units
# ---------------------------------------------------------------------------

def test_shadow_state_lifecycle():
    sh = ShadowState(num_layers=2, batch=2, max_len=16, kv_heads=2,
                     head_dim=4)
    rng = np.random.default_rng(0)
    k = rng.normal(size=(2, 3, 2, 4)).astype(np.float32)
    layers = [{"k": k, "v": k + 1}, {"k": k * 2, "v": k - 1}]
    pos = np.array([[0, 1, 2], [5, 6, -1]], np.int32)
    sh.record_step(layers, pos, np.array([3, 2]))
    assert sh.written[0, :3].all() and not sh.written[0, 3:].any()
    assert sh.written[1, 5:7].all() and not sh.written[1, :5].any()
    np.testing.assert_array_equal(sh.k[0, 0, :3], k[0])
    np.testing.assert_array_equal(sh.k[1, 1, 5:7], 2 * k[1, :2])
    # adoption copies the prefix history; reset clears the row
    sh.adopt(1, 0, 3)
    assert sh.written[1, :3].all()
    np.testing.assert_array_equal(sh.v[1, 1, :3], (k - 1)[0])
    sh.reset_row(0)
    assert not sh.written[0].any()
    assert sh.nbytes() > 0
    # out-of-range positions are dropped, not wrapped
    sh.record_step(layers, np.array([[99, -1, -1], [-1, -1, -1]], np.int32),
                   np.array([1, 0]))
    assert not sh.written[0].any()


def test_full_attention_matches_manual_softmax():
    rng = np.random.default_rng(1)
    H, KV, hd, S = 4, 2, 8, 6
    q = rng.normal(size=(H, hd)).astype(np.float32)
    k = rng.normal(size=(S, KV, hd)).astype(np.float32)
    v = rng.normal(size=(S, KV, hd)).astype(np.float32)
    mask = np.array([True, True, False, True, True, True])
    o, p = _full_attention(q, k, v, mask)
    assert o.shape == (H, hd) and p.shape == (KV, H // KV, S)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-6)
    assert (p[..., ~mask] == 0).all()
    g = 0
    s = (q.reshape(KV, H // KV, hd)[0, g] @ k[:, 0].T) / np.sqrt(hd)
    s[~mask] = -np.inf
    e = np.exp(s - s.max())
    ref = (e / e.sum()) @ v[:, 0]
    np.testing.assert_allclose(o.reshape(KV, H // KV, hd)[0, g], ref,
                               atol=1e-5)


def test_run_probe_zero_when_nothing_evicted():
    """If the pruned path kept every position and computed the same
    attention, divergence and evicted mass are both ~zero."""
    rng = np.random.default_rng(2)
    H = KV = 2
    hd, S = 4, 5
    sh = ShadowState(1, 1, 16, KV, hd)
    k = rng.normal(size=(1, S, KV, hd)).astype(np.float32)
    v = rng.normal(size=(1, S, KV, hd)).astype(np.float32)
    q = rng.normal(size=(1, S, H, hd)).astype(np.float32)
    pos = np.arange(S, dtype=np.int32)[None]
    sh.record_step([{"k": k, "v": v}], pos, np.array([S]))
    o, _ = _full_attention(q[0, -1], k[0], v[0], np.ones(S, bool))
    tap = {"q": q, "o": np.zeros((1, S, H, hd), np.float32),
           "live_pos": pos.copy()}
    tap["o"][0, -1] = o       # only the last token's output is probed
    out = run_probe(sh, [tap], pos, np.array([S]), rows=[0])
    assert len(out) == 1
    assert out[0]["tokens_evicted"] == 0
    assert out[0]["divergence"][0] < 1e-6
    assert out[0]["evicted_mass"][0] == 0.0
    # now pretend the pruned cache dropped the first two positions
    tap["live_pos"] = pos.copy()
    tap["live_pos"][0, :2] = -1
    out = run_probe(sh, [tap], pos, np.array([S]), rows=[0])
    assert out[0]["tokens_evicted"] == 2
    assert out[0]["evicted_mass"][0] > 0


def test_probe_record_and_summary():
    sample = {"slot": 1, "pos": 17, "divergence": [0.1, 0.2],
              "evicted_mass": [0.05, 0.0], "tokens_evicted": 8}
    rec = probe_record(sample, step=4, request_id=3)
    assert validate_event(rec) == []
    assert rec["rec"] == "probe" and rec["request_id"] == "3"
    assert summarize_request([]) is None
    summ = summarize_request([sample, dict(sample, divergence=[0.3, 0.4])])
    assert summ["probes"] == 2
    assert summ["max_divergence"] == pytest.approx(0.35)
    assert summ["tokens_evicted_last"] == 8
    assert list(REGRET_BOUNDS) == sorted(REGRET_BOUNDS)


# ---------------------------------------------------------------------------
# engine-level gates
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_pruned():
    return regret_smoke("paged_eviction", budget=32)


def test_paged_eviction_regret_nonzero(smoke_pruned):
    s = smoke_pruned
    assert s["probes"] > 0
    assert s["mean_divergence"] > 1e-5
    assert s["mean_evicted_mass"] > 1e-4
    assert s["shadow_mb"] > 0


def test_full_cache_regret_near_zero():
    s = regret_smoke("full", budget=1024)
    assert s["probes"] > 0
    assert s["mean_divergence"] < 1e-3
    assert s["mean_evicted_mass"] < 1e-6


def _engine(obs, policy="paged_eviction", budget=32):
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=budget, policy=policy,
                       dtype="float32")
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=3,
                  max_prompt_len=48, max_new_tokens=6,
                  sampling=SamplingParams(greedy=True), chunk_size=16,
                  obs=obs)


def _run_outputs(eng, seed=9):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, eng.cfg.vocab_size, size=24)
    for _ in range(4):
        tail = rng.integers(0, eng.cfg.vocab_size, size=12)
        eng.submit(np.concatenate([prefix, tail]).astype(np.int32))
    done = eng.run()
    return [r.output_tokens for r in done], done


def test_probes_do_not_perturb_outputs():
    """Probes OFF must match the plain engine bit-for-bit (regret_every is
    python-static — same compiled program), and probes ON are read-only
    taps: the sampled tokens are identical either way."""
    off, _ = _run_outputs(_engine(ObsConfig()))
    off2, _ = _run_outputs(_engine(ObsConfig(regret_every=0)))
    on, done = _run_outputs(_engine(ObsConfig(regret_every=2)))
    assert off == off2 == on
    assert any(r.regret_samples for r in done)


def test_probes_off_program_has_no_taps():
    """regret_every == 0 keeps the step jaxpr free of the tap outputs — the
    probes-off program is the pre-forensics program, not a variant that
    computes-and-discards."""
    off = _engine(ObsConfig())
    on = _engine(ObsConfig(regret_every=4))
    B = off.max_batch
    import jax.numpy as jnp
    args = (off.params, jnp.zeros((B, 1), jnp.int32),
            jnp.ones((B,), jnp.int32), jnp.ones((B,), bool),
            jnp.zeros((B,), bool), jnp.zeros((B,), bool),
            jnp.full((B,), -1, jnp.int32), jnp.zeros((B,), jnp.int32),
            off.cache, jax.random.PRNGKey(0))
    n_off = len(jax.eval_shape(off._step_impl, *args))
    args_on = args[:8] + (on.cache, args[9])
    out_on = jax.eval_shape(on._step_impl, *args_on)
    assert n_off == len(out_on) == 4
    assert jax.eval_shape(off._step_impl, *args)[3] is None
    assert out_on[3] is not None


def test_probe_records_on_trace_and_summaries(tmp_path):
    trace = tmp_path / "t.jsonl"
    eng = _engine(ObsConfig(regret_every=2, trace_path=str(trace)))
    _, done = _run_outputs(eng)
    eng.close()
    recs = [json.loads(ln) for ln in trace.read_text().splitlines()]
    probes = [r for r in recs if r.get("rec") == "probe"]
    assert probes
    for r in probes:
        assert validate_event(r) == []
        assert len(r["divergence"]) == len(r["evicted_mass"]) > 0
    assert sum(len(r.regret_samples) for r in done) == len(probes)
    summs = [r.regret_summary() for r in done]
    assert any(s and s["probes"] > 0 for s in summs)
    snap = eng.metrics_snapshot()
    assert snap["engine.eviction_regret"]["count"] == len(probes)
    assert snap["engine.evicted_attention_mass"]["count"] == len(probes)
    # request.probe == False opts a request out of sampling
    eng2 = _engine(ObsConfig(regret_every=2))
    rng = np.random.default_rng(9)
    reqs = []
    for _ in range(3):
        r = eng2.submit(rng.integers(0, eng2.cfg.vocab_size, size=24)
                        .astype(np.int32))
        r.probe = False
        reqs.append(r)
    eng2.run()
    assert all(not r.regret_samples for r in reqs)
