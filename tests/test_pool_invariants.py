"""Shared page-pool invariants under random write/evict/rollover sequences.

The free-list protocol (DESIGN.md §2) promises, after EVERY post_write:

  F1  allocated + free == N_pool                (free-list conservation)
  F2  ref_count[p] == #block-table entries mapping physical page p, ACROSS
      all requests — prefix sharing legitimately drives counts above 1
  F3  no physical page is mapped twice by the SAME block table (cross-
      request double-mapping is exactly what prefix sharing is)
  F4  free pages hold no live tokens (pos rows all -1)
  B1  total_valid() <= cache_budget + page_size for every eviction policy
      (the working page just filled is transiently over budget by at most
      one page — the paper's Alg.3 semantics; `full` is exempt)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CacheConfig
from repro.core import (
    POLICIES,
    append_chunk,
    decode_append,
    evict_page,
    get_policy,
    init_layer_cache,
    release_rows,
)


def _assert_pool_invariants(cache, ctx=""):
    ref = np.asarray(cache.ref_count)
    bt = np.asarray(cache.block_table)
    mapped = bt[bt >= 0]
    # F3: no double-mapping WITHIN a single request's block table (two
    # requests mapping the same page is prefix sharing, and is legal)
    for b in range(bt.shape[0]):
        row = bt[b][bt[b] >= 0]
        assert len(row) == len(set(row.tolist())), (ctx, b, "double-mapped")
    # F2: ref_count mirrors the block tables exactly (counts > 1 == shared)
    counts = np.bincount(mapped, minlength=cache.pool_pages)
    np.testing.assert_array_equal(counts, ref, err_msg=f"{ctx}: refcounts")
    assert (ref >= 0).all(), (ctx, "refcount underflow")
    # F1: conservation — every page is either mapped somewhere or free
    assert int((ref > 0).sum()) + int((ref == 0).sum()) == cache.pool_pages
    assert int((ref > 0).sum()) == len(set(mapped.tolist())), (
        ctx, "conservation")
    # F4: free pages are empty
    pos = np.asarray(cache.pos)
    assert (pos[ref == 0] == -1).all(), (ctx, "free page holds live tokens")


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("seed", [0, 1])
def test_pool_invariants_under_random_decode(policy, seed):
    page, budget = 4, 16
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    steps = 70
    B = 3
    cache = init_layer_cache(B, pol.slab_pages(cfg, steps), page, 2, 8,
                             jnp.float32)
    rng = jax.random.PRNGKey(seed)
    for t in range(steps):
        rng, k1, k2, k3 = jax.random.split(rng, 4)
        # random active mask exercises partially-idle batches
        active = jax.random.uniform(k3, (B,)) < 0.8
        out = decode_append(cache, jax.random.normal(k1, (B, 2, 8)),
                            jax.random.normal(k2, (B, 2, 8)),
                            jnp.full((B,), t), pol, cfg, active=active)
        cache = out.cache
        _assert_pool_invariants(cache, f"{policy} step {t}")
        if policy != "full":
            tv = np.asarray(cache.total_valid())
            assert (tv <= budget + page).all(), (policy, t, tv)


@pytest.mark.parametrize("policy", ["paged_eviction", "streaming_llm"])
def test_evicted_pages_become_other_requests_headroom(policy):
    """The tentpole behavior: pages a retiring request releases must be
    reusable by a DIFFERENT request (impossible under the old per-request
    slabs, where freed slots stayed inside the owner's private slab)."""
    page, budget = 4, 8
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    B = 2
    P = pol.slab_pages(cfg, 40)
    cache = init_layer_cache(B, P, page, 1, 8, jnp.float32)
    rng = jax.random.PRNGKey(0)
    for t in range(20):
        rng, k1, k2 = jax.random.split(rng, 3)
        cache = decode_append(cache, jax.random.normal(k1, (B, 1, 8)),
                              jax.random.normal(k2, (B, 1, 8)),
                              jnp.full((B,), t), pol, cfg).cache
    bt = np.asarray(cache.block_table)
    req1_pages = set(bt[1][bt[1] >= 0].tolist())
    assert req1_pages, "request 1 holds pages before retiring"
    # retire request 1: every logical slot's page goes back to the pool
    for slot in range(P):
        cache = evict_page(cache, jnp.full((B,), slot),
                           enable=jnp.array([False, True]))
    _assert_pool_invariants(cache, "after retire")
    # request 0 keeps decoding alone; its rollovers must pick up pages the
    # retired request freed
    req0_later = set()
    for t in range(20, 40):
        rng, k1, k2 = jax.random.split(rng, 3)
        cache = decode_append(cache, jax.random.normal(k1, (B, 1, 8)),
                              jax.random.normal(k2, (B, 1, 8)),
                              jnp.full((B,), t), pol, cfg,
                              active=jnp.array([True, False])).cache
        bt = np.asarray(cache.block_table)
        req0_later.update(bt[0][bt[0] >= 0].tolist())
    assert req0_later & req1_pages, (
        "request 0 never reused a page the retired request freed — pool is "
        "not actually shared")
    _assert_pool_invariants(cache, "end")


def test_explicit_evict_page_frees_and_release_then_append_reuses():
    """evict_page returns pages to the free list; release_rows + append_chunk
    (the unified-step admission path, replacing the old insert splice)
    draws from it without disturbing other rows."""
    page = 4
    cache = init_layer_cache(3, 4, page, 1, 8, jnp.float32)
    rng = jax.random.PRNGKey(2)
    pol = get_policy("full")
    cfg = CacheConfig(page_size=page, cache_budget=16, policy="full",
                      dtype="float32")
    for t in range(10):
        rng, k1, k2 = jax.random.split(rng, 3)
        cache = decode_append(cache, jax.random.normal(k1, (3, 1, 8)),
                              jax.random.normal(k2, (3, 1, 8)),
                              jnp.full((3,), t), pol, cfg).cache
    free0 = int(cache.num_free())
    cache = evict_page(cache, jnp.array([0, 0, 0]),
                       enable=jnp.array([True, False, False]))
    assert int(cache.num_free()) == free0 + 1
    _assert_pool_invariants(cache, "after explicit evict")

    # row 0 retires; a new request's first chunk prefills in place
    before_row2 = np.asarray(cache.pos_view()[2])
    cache = release_rows(cache, jnp.array([True, False, False]))
    _assert_pool_invariants(cache, "after release")
    T = 6
    rng, k1, k2 = jax.random.split(rng, 3)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (3, T))
    n_tok = jnp.array([T, 0, 0])
    pos = jnp.where(jnp.arange(T)[None] < n_tok[:, None], pos, -1)
    cache = append_chunk(cache, jax.random.normal(k1, (3, T, 1, 8)),
                         jax.random.normal(k2, (3, T, 1, 8)),
                         pos, jnp.zeros((3, T)), n_tok)
    _assert_pool_invariants(cache, "after admission chunk")
    got = np.sort(np.asarray(cache.pos_view()[0]).reshape(-1))
    np.testing.assert_array_equal(got[-T:], np.arange(T))
    np.testing.assert_array_equal(np.asarray(cache.pos_view()[2]), before_row2)


def test_budget_bound_after_every_post_write():
    """B1 for every registered eviction policy, long trace, page 8."""
    page, budget = 8, 32
    for policy in sorted(POLICIES):
        if policy == "full":
            continue
        pol = get_policy(policy)
        cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                          dtype="float32")
        cache = init_layer_cache(2, pol.slab_pages(cfg, 100), page, 1, 8,
                                 jnp.float32)
        rng = jax.random.PRNGKey(4)
        for t in range(100):
            rng, k1, k2 = jax.random.split(rng, 3)
            cache = decode_append(cache, jax.random.normal(k1, (2, 1, 8)),
                                  jax.random.normal(k2, (2, 1, 8)),
                                  jnp.full((2,), t), pol, cfg).cache
            tv = np.asarray(cache.total_valid())
            assert (tv <= budget + page).all(), (policy, t, tv)
        _assert_pool_invariants(cache, policy)
