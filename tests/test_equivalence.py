"""Cross-path equivalence tests — the strongest correctness evidence:

1. full-cache prefill+decode == contiguous forward (every arch family's
   attention/mamba/xlstm decode path reproduces the training forward)
2. mLSTM chunkwise-parallel == exact recurrent step scan
3. mamba full-sequence scan == prefill + decode-step continuation
4. attention blocked (flash-style jnp) == full-matrix reference
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.core import get_policy
from repro.models import (
    decode_step,
    forward_prefill,
    forward_train,
    init_model,
    make_inputs,
)
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import blocked_causal_attention, full_causal_attention

pytestmark = pytest.mark.slow  # heavy tier: full suite only

EQ_ARCHS = ["qwen2.5-3b", "stablelm-3b", "gemma3-27b", "mixtral-8x7b",
            "jamba-1.5-large-398b", "xlstm-1.3b", "musicgen-medium",
            "chameleon-34b"]


@pytest.mark.parametrize("arch", EQ_ARCHS)
def test_full_cache_decode_matches_contiguous(arch):
    """Teacher-forced decode over a full (non-evicting) cache must produce
    the same logits as the contiguous training forward pass."""
    cfg = ASSIGNED_ARCHS[arch].reduced()
    if cfg.num_experts:
        # capacity-dropping is a train-mode approximation; decode computes
        # the exact top-k combine. Equivalence needs drop-free capacity.
        from dataclasses import replace
        cfg = replace(cfg, moe_capacity_factor=float(cfg.num_experts))
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S, T = 2, 32, 6                     # prefill 32 tokens, decode 6 more
    inp = make_inputs(jax.random.PRNGKey(1), cfg, B, S + T)
    tokens = inp["tokens"]
    logits_all, _ = forward_train(params, cfg, tokens, cond=inp["cond"],
                                  remat=False)

    pol = get_policy("full")
    ccfg = CacheConfig(page_size=8, cache_budget=64, policy="full",
                       dtype="float32")
    prompt = tokens[..., :S] if cfg.num_codebooks > 1 else tokens[:, :S]
    lg, cache = forward_prefill(params, cfg, prompt, pol, ccfg,
                                cond=inp["cond"], total_seq_hint=S + T)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_all[:, S - 1]), rtol=2e-3, atol=2e-3)
    for t in range(T - 1):
        step_tok = tokens[..., S + t] if cfg.num_codebooks > 1 \
            else tokens[:, S + t]
        lg, cache = decode_step(params, cfg, step_tok, cache, pol, ccfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_all[:, S + t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode step {t} diverges from contiguous")


def test_mlstm_chunkwise_matches_stepwise():
    cfg = ASSIGNED_ARCHS["xlstm-1.3b"].reduced()
    p = xlstm_mod.init_mlstm(jax.random.PRNGKey(0), cfg)
    B, S, D = 2, 64, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) * 0.5
    out_chunk = xlstm_mod.mlstm_chunkwise(p, cfg, x, chunk=16)
    # exact recurrence, one token at a time
    st = xlstm_mod.mlstm_init_state(cfg, B, x.dtype)
    outs = []
    for t in range(S):
        o, st = xlstm_mod.mlstm_decode_step(p, cfg, x[:, t], st)
        outs.append(o)
    out_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_size_invariance():
    cfg = ASSIGNED_ARCHS["xlstm-1.3b"].reduced()
    p = xlstm_mod.init_mlstm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    a = xlstm_mod.mlstm_chunkwise(p, cfg, x, chunk=8)
    b = xlstm_mod.mlstm_chunkwise(p, cfg, x, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_mlstm_prefill_state_continues_decode():
    cfg = ASSIGNED_ARCHS["xlstm-1.3b"].reduced()
    p = xlstm_mod.init_mlstm(jax.random.PRNGKey(0), cfg)
    B, S, T = 1, 32, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + T, cfg.d_model))
    full = xlstm_mod.mlstm_chunkwise(p, cfg, x, chunk=8)
    pre, st = xlstm_mod.mlstm_chunkwise(p, cfg, x[:, :S], chunk=8,
                                        return_state=True)
    for t in range(T):
        o, st = xlstm_mod.mlstm_decode_step(p, cfg, x[:, S + t], st)
        np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, S + t]),
                                   rtol=2e-4, atol=2e-4)


def test_slstm_prefill_state_continues_decode():
    cfg = ASSIGNED_ARCHS["xlstm-1.3b"].reduced()
    p = xlstm_mod.init_slstm(jax.random.PRNGKey(0), cfg)
    B, S, T = 2, 24, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + T, cfg.d_model))
    full = xlstm_mod.slstm_forward(p, cfg, x)
    _, st = xlstm_mod.slstm_forward(p, cfg, x[:, :S], return_state=True)
    for t in range(T):
        o, st = xlstm_mod.slstm_decode_step(p, cfg, x[:, S + t], st)
        np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, S + t]),
                                   rtol=1e-4, atol=1e-4)


def test_mamba_prefill_state_continues_decode():
    cfg = ASSIGNED_ARCHS["jamba-1.5-large-398b"].reduced()
    p = mamba_mod.init_mamba(jax.random.PRNGKey(0), cfg)
    B, S, T = 2, 24, 5
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + T, cfg.d_model))
    full = mamba_mod.mamba_forward(p, cfg, x)
    _, st = mamba_mod.mamba_prefill(p, cfg, x[:, :S])
    for t in range(T):
        o, st = mamba_mod.mamba_decode_step(p, cfg, x[:, S + t], st)
        np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, S + t]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [0, 48])
def test_blocked_attention_matches_full(window):
    key = jax.random.PRNGKey(3)
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    a = blocked_causal_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                 window=window, q_chunk=32, kv_chunk=32)
    b = full_causal_attention(q, k, v, q_positions=pos, kv_positions=pos,
                              window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=3e-5)
