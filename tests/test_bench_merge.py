"""BENCH artifact merge semantics (benchmarks/common.merge_json).

latency.py, throughput.py and accuracy.py --regret all land sections in
one BENCH_latency.json — each writer must merge its key without
clobbering the others', and a corrupt/partial existing file must degrade
to a fresh object instead of crashing the benchmark run.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import merge_json  # noqa: E402


def test_merge_creates_and_preserves(tmp_path):
    p = tmp_path / "BENCH_latency.json"
    merge_json(p, "tpot_ms", {"paged_eviction": 1.5})
    merge_json(p, "throughput_percentiles", {"p50": 2.0})
    out = json.loads(p.read_text())
    assert out == {"tpot_ms": {"paged_eviction": 1.5},
                   "throughput_percentiles": {"p50": 2.0}}
    # re-landing a section replaces only that section
    merge_json(p, "tpot_ms", {"paged_eviction": 1.2, "full": 1.0})
    out = json.loads(p.read_text())
    assert out["tpot_ms"] == {"paged_eviction": 1.2, "full": 1.0}
    assert out["throughput_percentiles"] == {"p50": 2.0}


def test_merge_survives_corrupt_existing_file(tmp_path):
    p = tmp_path / "BENCH_latency.json"
    p.write_text('{"tpot_ms": {bad json')          # truncated write
    merge_json(p, "regret", {"probes": 4})
    assert json.loads(p.read_text()) == {"regret": {"probes": 4}}


def test_merge_survives_non_object_existing_file(tmp_path):
    p = tmp_path / "BENCH_latency.json"
    p.write_text("[1, 2, 3]\n")                    # valid JSON, wrong shape
    merge_json(p, "setup", {"arch": "qwen2.5-3b"})
    assert json.loads(p.read_text()) == {"setup": {"arch": "qwen2.5-3b"}}


def test_merge_survives_empty_file(tmp_path):
    p = tmp_path / "BENCH_latency.json"
    p.write_text("")
    merge_json(p, "a", 1)
    merge_json(p, "b", None)                       # null values are kept
    assert json.loads(p.read_text()) == {"a": 1, "b": None}


def test_merge_output_is_valid_json_with_trailing_newline(tmp_path):
    p = tmp_path / "BENCH_latency.json"
    merge_json(p, "k", {"nested": [1, 2]})
    text = p.read_text()
    assert text.endswith("\n")
    assert json.loads(text) == {"k": {"nested": [1, 2]}}
