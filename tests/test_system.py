"""End-to-end behaviour tests for the paper's system-level claims.

Each test is an executable version of a claim from the paper:
  C1  eviction frequency: PagedEviction does ~1/page_size the eviction work
      of token-per-step baselines (Limitation 4 / throughput claim)
  C2  memory: the budget bounds the live cache for every eviction policy
      while full cache grows linearly (the memory claim)
  C3  block structure: PagedEviction keeps pages uniformly full; unstructured
      baselines fragment (Limitation 1, Figs. 5/6)
  C4  the mechanism end-to-end stays finite and budget-true through the
      serving engine (the accuracy ordering itself — Fig. 2 proxy — is
      measured in benchmarks/accuracy.py on a trained tiny model)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache
from repro.models import init_model
from repro.serving import Engine

pytestmark = pytest.mark.slow  # heavy tier: full suite only


def _trace_outcomes(policy, steps=64, budget=16, page=4):
    pol = get_policy(policy)
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype="float32")
    cache = init_layer_cache(1, pol.slab_pages(cfg, steps), page, 1, 8,
                             jnp.float32)
    rng = jax.random.PRNGKey(0)
    n_evictions = 0
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache, jax.random.normal(k1, (1, 1, 8)),
                            jax.random.normal(k2, (1, 1, 8)),
                            jnp.full((1,), t), pol, cfg)
        cache = out.cache
        n_evictions += int(out.pages_evicted.any()) + int(out.tokens_evicted.any())
    return cache, n_evictions


def test_c1_eviction_frequency_ratio():
    _, paged = _trace_outcomes("paged_eviction")
    _, stream = _trace_outcomes("streaming_llm")
    _, unstr = _trace_outcomes("inverse_key_l2")
    # token-per-step policies evict every step at steady state; paged only
    # at page boundaries: ~1/page_size the operations
    assert stream >= 4 * paged - 4
    assert unstr >= 4 * paged - 4
    assert paged > 0


def test_c2_budget_bounds_memory():
    for policy in ("paged_eviction", "streaming_llm", "inverse_key_l2",
                   "keydiff"):
        cache, _ = _trace_outcomes(policy, steps=80, budget=16, page=4)
        assert int(cache.total_valid()[0]) <= 16 + 4, policy
    full, _ = _trace_outcomes("full", steps=80)
    assert int(full.total_valid()[0]) == 80


def test_c3_structure_preserved_only_by_paged():
    paged, _ = _trace_outcomes("paged_eviction", steps=77)
    tpp = np.asarray(paged.tokens_per_page())[0]
    cur = int(paged.cur_page[0])
    assert all(n in (0, 4) for i, n in enumerate(tpp) if i != cur)

    unstr, _ = _trace_outcomes("inverse_key_l2", steps=77)
    tpp_u = np.asarray(unstr.tokens_per_page())[0]
    cur_u = int(unstr.cur_page[0])
    partial = [n for i, n in enumerate(tpp_u) if i != cur_u and 0 < n < 4]
    assert partial, "unstructured eviction must fragment pages"


@pytest.mark.parametrize("policy", ["paged_eviction", "streaming_llm", "full"])
def test_c4_engine_end_to_end_budget_true(policy):
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy=policy,
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=2, max_prompt_len=64,
                 max_new_tokens=16)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=60).astype(np.int32))
            for _ in range(3)]
    eng.run()
    assert all(r.num_generated == 16 for r in reqs)
    kv = jax.tree.map(lambda a: a[0], eng.cache.pattern[0].kv)
    if policy != "full":
        assert int(kv.total_valid().max()) <= 32 + 8
    for r in reqs:
        assert all(0 <= t < cfg.vocab_size for t in r.output_tokens)
