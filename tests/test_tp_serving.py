"""Tensor-parallel serving (DESIGN.md §11): the shard_map'd unified step
over a (1, tp) mesh must be observationally identical to tp=1 — same greedy
tokens, same eviction victims, same pool metadata, exactly-reconciling
devstats and lineage — while holding ~1/tp of the pool payload per device.

The multi-device tests need >= 4 devices; the CI mesh tier provides them
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (set before the
first jax import — see .github/workflows). Under the plain 1-device tier
they skip; the validation tests at the bottom always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig, get_arch
from repro.core import devstats
from repro.launch.mesh import make_tp_mesh
from repro.models.transformer import init_model
from repro.obs import ObsConfig
from repro.serving import Engine, SamplingParams
from repro.sharding import rules

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_"
           "count=4 before jax import)")


def _make_engine(tp, arch="gemma3-27b", policy="paged_eviction",
                 dtype="float32", obs=None, use_pallas=False, budget=32,
                 page=4, new_tokens=6):
    """Every TP degree runs the SAME reduced(tp=4) config — parity compares
    like with like; only the mesh degree varies."""
    cfg = get_arch(arch).reduced(tp=4)
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype=dtype)
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=3,
                  max_prompt_len=40, max_new_tokens=new_tokens,
                  sampling=SamplingParams(greedy=True), chunk_size=16,
                  seed=0, tp=tp, use_pallas=use_pallas,
                  obs=obs if obs is not None else ObsConfig())


def _submit_churn(eng, seed=0, n_reqs=5):
    """Shared-prefix workload that exercises adoption, CoW forks, eviction
    and slot reuse (n_reqs > max_batch)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, eng.cfg.vocab_size, size=16)
    for i in range(n_reqs):
        tail = rng.integers(0, eng.cfg.vocab_size, size=8 + i)
        eng.submit(np.concatenate([shared, tail]).astype(np.int32))


def _run_outputs(eng):
    done = eng.run(max_steps=300)
    return {r.request_id: list(r.output_tokens) for r in done}


def _metadata_arrays(eng):
    """Replicated pool metadata per layer, fetched to host."""
    out = []
    for lc in list(eng.cache.pattern) + list(eng.cache.tail):
        if lc.kv is None:
            continue
        out.append({k: np.asarray(jax.device_get(getattr(lc.kv, k)))
                    for k in ("pos", "score", "block_table", "ref_count",
                              "cur_page", "cur_off")})
    return out


# ---------------------------------------------------------------- parity ---

@needs_mesh
@pytest.mark.parametrize("dtype", ["float32", "int8"])
@pytest.mark.parametrize("policy", ["paged_eviction", "streaming_llm",
                                    "full"])
def test_tp_output_parity(policy, dtype):
    """TP in {1, 2, 4} produce identical greedy tokens on a churned
    shared-prefix workload, for page eviction, token eviction and the
    uncompressed baseline, in both f32 and quantised int8 pools."""
    outs = {}
    for tp in (1, 2, 4):
        eng = _make_engine(tp, policy=policy, dtype=dtype)
        _submit_churn(eng)
        outs[tp] = _run_outputs(eng)
        eng.close()
    assert outs[1] == outs[2], (policy, dtype)
    assert outs[1] == outs[4], (policy, dtype)


@needs_mesh
def test_tp_parity_pallas_kernels():
    """The Pallas split-K decode + G-fold prefill kernels run per-shard on
    the KV-head-sharded pool and still match tp=1 exactly."""
    outs = {}
    for tp in (1, 4):
        eng = _make_engine(tp, use_pallas=True)
        _submit_churn(eng)
        outs[tp] = _run_outputs(eng)
        eng.close()
    assert outs[1] == outs[4]


@needs_mesh
def test_tp_parity_moe():
    """Expert-sharded MoE (mixtral): replicated f32 router + psum'd expert
    outputs keep routing and tokens identical across degrees."""
    outs = {}
    for tp in (1, 4):
        eng = _make_engine(tp, arch="mixtral-8x7b")
        _submit_churn(eng)
        outs[tp] = _run_outputs(eng)
        eng.close()
    assert outs[1] == outs[4]


# ------------------------------------------------- pool state under TP ---

@needs_mesh
def test_tp_pool_bytes_scale():
    """TP=N holds <= 1/N of the tp=1 pool payload on every device (exact
    here: the KV-head dim splits evenly), metadata replicated."""
    sizes = {}
    for tp in (1, 2, 4):
        eng = _make_engine(tp)
        sizes[tp] = eng.pool_bytes()
        eng.close()
    total = sizes[1]["payload_total"]
    for tp in (1, 2, 4):
        assert sizes[tp]["payload_total"] == total
        assert sizes[tp]["per_device_max"] == total // tp, (tp, sizes)
        assert sizes[tp]["devices"] == tp


def _iter_reps(md):
    """Pattern layers are scan-stacked: metadata may carry a leading reps
    dim (ref_count (R, P), block_table (R, B, pages), pos (R, P, page)).
    Yield per-rep {ref_count, block_table, pos} dicts either way."""
    ref = md["ref_count"]
    if ref.ndim == 1:
        yield md
        return
    for r in range(ref.shape[0]):
        yield {k: md[k][r] for k in ("ref_count", "block_table", "pos")}


def _assert_pool_invariants(md, ctx=""):
    """F1-F4 from tests/test_pool_invariants.py over one metadata replica."""
    ref, bt, pos = md["ref_count"], md["block_table"], md["pos"]
    pool_pages = ref.shape[0]
    mapped = bt[bt >= 0]
    for b in range(bt.shape[0]):    # F3: no double-mapping within a request
        row = bt[b][bt[b] >= 0]
        assert len(row) == len(set(row.tolist())), (ctx, b, "double-mapped")
    counts = np.bincount(mapped, minlength=pool_pages)
    np.testing.assert_array_equal(counts, ref,
                                  err_msg=f"{ctx}: refcounts")   # F2
    assert (ref >= 0).all(), (ctx, "refcount underflow")
    assert int((ref > 0).sum()) == len(set(mapped.tolist())), (
        ctx, "conservation")                                      # F1
    assert (pos[ref == 0] == -1).all(), (ctx, "free page holds tokens")  # F4


@needs_mesh
def test_tp_pool_invariants_per_shard():
    """After a churned tp=4 run, EVERY device's replica of the pool
    metadata satisfies F1-F4 and all replicas are bit-identical — the
    allocator ran the same trajectory on all shards."""
    eng = _make_engine(4)
    _submit_churn(eng)
    _run_outputs(eng)
    for li, lc in enumerate(list(eng.cache.pattern) + list(eng.cache.tail)):
        if lc.kv is None:
            continue
        per_dev = {}
        for name in ("ref_count", "block_table", "pos"):
            leaf = getattr(lc.kv, name)
            shards = {s.device.id: np.asarray(s.data)
                      for s in leaf.addressable_shards}
            assert len(shards) == 4, (li, name)
            per_dev[name] = shards
        ref = None
        for dev in sorted(per_dev["ref_count"]):
            md = {name: per_dev[name][dev]
                  for name in ("ref_count", "block_table", "pos")}
            for ri, rep in enumerate(_iter_reps(md)):
                _assert_pool_invariants(
                    rep, ctx=f"layer {li} rep {ri} dev {dev}")
            if ref is None:
                ref = md
            else:
                for name, arr in md.items():
                    np.testing.assert_array_equal(
                        arr, ref[name],
                        err_msg=f"layer {li} dev {dev} {name} diverged")
    eng.close()


@needs_mesh
def test_tp_eviction_victims_identical():
    """The pmean'd page scores make PagedEviction's argmin pick the SAME
    victim on every shard and at every degree: final pos/block_table/
    ref_count match tp=1 exactly, lineage evict/free event counts match."""
    state = {}
    for tp in (1, 4):
        eng = _make_engine(tp, obs=ObsConfig(lineage=True), budget=24,
                           new_tokens=8)
        _submit_churn(eng, n_reqs=6)
        _run_outputs(eng)
        state[tp] = (_metadata_arrays(eng), dict(eng.obs.ledger.counts()))
    md1, led1 = state[1]
    md4, led4 = state[4]
    assert led4 == led1 and led1.get("evict", 0) > 0, (led1, led4)
    assert len(md1) == len(md4)
    for li, (a, b) in enumerate(zip(md1, md4)):
        for name in ("pos", "block_table", "ref_count", "cur_page",
                     "cur_off"):
            np.testing.assert_array_equal(a[name], b[name],
                                          err_msg=f"layer {li} {name}")
        np.testing.assert_allclose(a["score"], b["score"], rtol=1e-5,
                                   atol=1e-6, err_msg=f"layer {li} score")


# ------------------------------------------- devstats / lineage under TP ---

def _host_pool_state(eng):
    ref_sum = free = mapped = 0
    for lc in list(eng.cache.pattern) + list(eng.cache.tail):
        if lc.kv is None:
            continue
        ref = np.asarray(jax.device_get(lc.kv.ref_count))
        bt = np.asarray(jax.device_get(lc.kv.block_table))
        ref_sum += int(ref.sum())
        free += int((ref == 0).sum())
        mapped += int((bt >= 0).sum())
    return ref_sum, free, mapped


@needs_mesh
def test_tp_devstats_reconcile_exactly():
    """PR 8's conservation identities hold EXACTLY at tp=4: the stats
    vector is psum'd from one shard's contribution inside the mapped step,
    so replication cannot double-count pool events."""
    eng = _make_engine(4, budget=24, new_tokens=8)
    _submit_churn(eng, n_reqs=6)
    reg = eng.obs.registry
    prev = _host_pool_state(eng)
    prev_ctr = {n: 0 for n in devstats.STAT_NAMES}
    steps = 0
    while eng.step() and steps < 300:
        steps += 1
        cur = _host_pool_state(eng)
        ctr = {n: reg.counter(f"pool.{n}").value
               for n in devstats.STAT_NAMES}
        d = {n: ctr[n] - prev_ctr[n] for n in ctr}
        assert cur[0] - prev[0] == (d["pages_allocated"] + d["pages_adopted"]
                                    - d["pages_released"]), (steps, d)
        assert cur[1] - prev[1] == d["pages_freed"] - d["pages_allocated"], \
            (steps, d)
        assert cur[2] == cur[0], (steps, cur)
        assert eng._free_pages_est == cur[1], (steps,)
        prev, prev_ctr = cur, ctr
    assert eng._free_pages_est == eng.pool_stats()["free_pages"]
    assert prev_ctr["pages_evicted"] > 0, "workload never evicted"
    eng.close()


@needs_mesh
def test_tp_devstats_match_tp1():
    """The cumulative pool counters after the same workload are identical
    at tp=1 and tp=4."""
    ctrs = {}
    for tp in (1, 4):
        eng = _make_engine(tp, budget=24, new_tokens=8)
        _submit_churn(eng, n_reqs=6)
        _run_outputs(eng)
        reg = eng.obs.registry
        ctrs[tp] = {n: reg.counter(f"pool.{n}").value
                    for n in devstats.STAT_NAMES}
        eng.close()
    assert ctrs[1] == ctrs[4]


@needs_mesh
def test_tp_lineage_reconciles_every_step():
    """The host ledger reconciles exactly against the (replicated) device
    snapshot after every tp=4 step — the snapshot gather reads one logical
    copy, never a concatenation of shards."""
    eng = _make_engine(4, obs=ObsConfig(lineage=True), budget=24,
                       new_tokens=8)
    _submit_churn(eng, n_reqs=6)
    steps = 0
    while eng.step() and steps < 300:
        steps += 1
        snap = jax.device_get(eng._lineage_fn(eng.cache))
        assert eng.obs.ledger.reconcile(snap) == [], f"step {steps}"
    assert eng.obs.ledger.counts().get("evict", 0) > 0
    eng.close()


# ----------------------------------------------- validation (always run) ---

def test_validate_tp_divisibility():
    cfg = get_arch("gemma3-27b").reduced()      # KV=2 at tp=1
    with pytest.raises(ValueError, match="not divisible"):
        rules.validate_tp(cfg, 4)
    rules.validate_tp(get_arch("gemma3-27b").reduced(tp=4), 4)


def test_validate_tp_rejects_non_attn_mixers():
    cfg = ASSIGNED_ARCHS["jamba-1.5-large-398b"].reduced(tp=4)
    with pytest.raises(ValueError, match="attention mixers"):
        rules.validate_tp(cfg, 4)


def test_validate_tp_rejects_cross_attention():
    cfg = ASSIGNED_ARCHS["musicgen-medium"].reduced(tp=4)
    with pytest.raises(ValueError, match="cross-attention"):
        rules.validate_tp(cfg, 4)


def test_reduced_tp_widens_heads():
    for name in ("gemma3-27b", "mixtral-8x7b", "qwen2.5-3b"):
        cfg = get_arch(name).reduced(tp=4)
        assert cfg.num_kv_heads % 4 == 0
        assert cfg.num_heads % 4 == 0
        assert cfg.num_heads % cfg.num_kv_heads == 0


def test_make_tp_mesh_requires_devices():
    with pytest.raises(ValueError, match="devices"):
        make_tp_mesh(len(jax.devices()) + 1)


def test_tp_rejects_regret_taps():
    cfg = get_arch("gemma3-27b").reduced(tp=4)
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices to construct a tp=2 engine")
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=4, cache_budget=32,
                       policy="paged_eviction", dtype="float32")
    with pytest.raises(ValueError, match="regret"):
        Engine(cfg, params, cache_cfg=ccfg, max_batch=2, max_prompt_len=32,
               max_new_tokens=4, sampling=SamplingParams(greedy=True),
               chunk_size=16, tp=2, obs=ObsConfig(regret_every=2))


def test_tp_param_specs_shape():
    """Spec builders put the KV/head axis where the engine expects it and
    leave everything else replicated."""
    from jax.sharding import PartitionSpec as P
    cfg = get_arch("gemma3-27b").reduced(tp=4)
    params = jax.eval_shape(lambda k: init_model(k, cfg),
                            jax.random.PRNGKey(0))
    specs = rules.tp_param_specs(params)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    flat_s = {jax.tree_util.keystr(kp): s for kp, s in
              jax.tree_util.tree_flatten_with_path(
                  specs, is_leaf=lambda x: isinstance(x, P))[0]}
    for kp, leaf in flat_p:
        ks = jax.tree_util.keystr(kp)
        spec = flat_s[ks]
        if "embed" in ks or "lm_head" in ks or "norm" in ks:
            assert spec == P(), (ks, spec)
        if "wo" in ks and "attn" in ks:
            assert rules.TP_AXIS in spec, (ks, spec)
