"""Sharding-rule tests: divisibility safety for every arch on the production
mesh shapes (via AbstractMesh — no 256 devices needed) + a real end-to-end
pjit run on a 1x1 mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, CacheConfig, DECODE_32K, TRAIN_4K
from repro.core import get_policy
from repro.models.transformer import init_decode_caches, init_model
from repro.sharding import rules
from repro.training.optimizer import init_adamw

# jax 0.4.37 constructor: a tuple of (name, size) pairs
SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _check_divisible(tree_shapes, tree_specs, mesh):
    """Every sharded dim must divide by its mesh axes — the property that
    makes .lower() succeed."""
    shapes = jax.tree.leaves(tree_shapes)
    specs = jax.tree.leaves(tree_specs, is_leaf=lambda x: isinstance(x, P))
    assert len(shapes) == len(specs)
    for shp, spec in zip(shapes, specs):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert shp.shape[d] % size == 0, (shp.shape, spec)


def _spec_tree(shardings):
    return jax.tree.map(lambda s: s.spec, shardings)


@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = ASSIGNED_ARCHS[arch]
    shapes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    sh = rules.param_shardings(mesh, cfg, shapes)
    _check_divisible(shapes, _spec_tree(sh), mesh)


@pytest.mark.parametrize("arch", sorted(ASSIGNED_ARCHS))
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_cache_specs_divisible(arch, mesh):
    cfg = ASSIGNED_ARCHS[arch]
    pol = get_policy("full")
    ccfg = CacheConfig(page_size=16, cache_budget=4096, policy="full",
                       slab_multiple=16)
    B = DECODE_32K.global_batch
    shapes = jax.eval_shape(
        lambda: init_decode_caches(cfg, B, DECODE_32K.seq_len, pol, ccfg))
    sh = rules.cache_shardings(mesh, cfg, shapes, B)
    _check_divisible(shapes, _spec_tree(sh), mesh)


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_opt_specs_divisible_zero1(mesh):
    cfg = ASSIGNED_ARCHS["mixtral-8x7b"]
    pshapes = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
    oshapes = jax.eval_shape(init_adamw, pshapes)
    psh = rules.param_shardings(mesh, cfg, pshapes)
    osh = rules.opt_shardings(mesh, cfg, oshapes, psh, zero1=True)
    _check_divisible(oshapes.mu, _spec_tree(osh.mu), mesh)


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_step_input_specs_divisible(mesh):
    """Unified-step / paged flash-prefill kernel operand specs (DESIGN.md
    §6): batch over DP, chunk-query heads over model iff divisible."""
    for arch in ("qwen2.5-3b", "mixtral-8x7b", "gemma3-27b"):
        cfg = ASSIGNED_ARCHS[arch]
        B, T = DECODE_32K.global_batch, 256
        sh = rules.step_input_shardings(mesh, cfg, B, T)
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        G = cfg.num_heads // KV
        shapes = {
            "tokens": jnp.zeros((B, T), jnp.int32),
            "n_tok": jnp.zeros((B,), jnp.int32),
            "mask": jnp.zeros((B,), bool),
            "share_src": jnp.zeros((B,), jnp.int32),
            "share_pages": jnp.zeros((B,), jnp.int32),
            "q": jnp.zeros((B, T, cfg.num_heads, hd)),
            "q_pos": jnp.zeros((B, T), jnp.int32),
            "block_table": jnp.zeros((B, 64), jnp.int32),
            "page_scores": jnp.zeros((B, 64), jnp.float32),
            "decode_partials": jnp.zeros((B, KV, 8, G, hd), jnp.float32),
            "epilogue_norms": jnp.zeros((B, KV, 64, 16), jnp.float32),
        }
        for name, spec in sh.items():
            _check_divisible([jax.eval_shape(lambda: shapes[name])],
                             [spec], mesh)
        # q heads must actually take the model axis when divisible
        msz = int(np.prod([mesh.shape[a] for a in ("model",)
                           if a in mesh.shape]))
        if cfg.num_heads % msz == 0 and msz > 1:
            assert sh["q"][2] is not None, arch
        # split-K partials / epilogue norms split kv heads iff divisible
        if msz > 1 and KV % msz == 0:
            assert sh["decode_partials"][1] is not None, arch
            assert sh["epilogue_norms"][1] is not None, arch


def test_batch_axes_fallbacks():
    assert rules.batch_axes(SINGLE, 256) == "data"
    assert rules.batch_axes(MULTI, 256) == ("pod", "data")
    assert rules.batch_axes(MULTI, 16) is None or \
        rules.batch_axes(MULTI, 16) == "data"
    assert rules.batch_axes(SINGLE, 1) is None      # long_500k single request


def test_end_to_end_pjit_tiny_mesh():
    """Whole train step through pjit with rule-derived shardings on the one
    real CPU device (semantics check of the sharded program)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    from repro.training import AdamWConfig, make_train_step
    opt = init_adamw(params)
    p_sh = rules.param_shardings(mesh, cfg, jax.eval_shape(lambda: params))
    o_sh = rules.opt_shardings(mesh, cfg, jax.eval_shape(lambda: opt), p_sh)
    step = make_train_step(cfg, AdamWConfig(total_steps=5, warmup_steps=1))
    B, S = 2, 32
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "targets": jnp.zeros((B, S), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    b_sh = rules.data_shardings(mesh, batch)
    with mesh:
        jstep = jax.jit(lambda p, o, b: step(p, o, b),
                        in_shardings=(p_sh, o_sh, b_sh),
                        out_shardings=(p_sh, o_sh, None))
        p2, o2, m = jstep(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
