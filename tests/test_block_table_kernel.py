"""Kernel/reference parity for the block-table decode kernel.

Drives REAL decode traces (write -> evict -> rollover through the shared
pool) so the caches under test contain freed-and-reallocated physical
pages, then checks the Pallas block-table kernel against the dense
attention oracle in ``kernels/ref.py`` to atol=1e-4 across
policies x page sizes x dtypes (f32 and int8), in interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache
from repro.kernels import ops, ref
from repro.models.attention import paged_attention_ref as model_ref

POLICIES = ["paged_eviction", "streaming_llm", "full"]
ATOL = 1e-4


def _driven_cache(policy, page, dtype, steps=None, B=2, KV=2, hd=64, seed=0):
    """Decode-trace a cache well past its budget so pages get evicted,
    returned to the pool, and reallocated."""
    budget = 2 * page
    cfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                      dtype=dtype)
    pol = get_policy(policy)
    steps = steps if steps is not None else budget + 3 * page + 3
    pages = pol.slab_pages(cfg, steps)
    cache = init_layer_cache(B, pages, page, KV, hd,
                             "int8" if dtype == "int8" else jnp.float32)
    rng = jax.random.PRNGKey(seed)
    evicted = 0
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache, jax.random.normal(k1, (B, KV, hd)),
                            jax.random.normal(k2, (B, KV, hd)),
                            jnp.full((B,), t), pol, cfg)
        cache = out.cache
        evicted += int(np.asarray(out.pages_evicted).sum()) + \
            int(np.asarray(out.tokens_evicted).sum())
    if policy != "full":
        assert evicted > 0, "trace must exercise eviction + reallocation"
    return cache, steps


def _dense_reference(q, cache, cur):
    """Dense oracle from kernels/ref.py on the gathered (dequantized) view."""
    B, H, hd = q.shape
    KV = cache.k.shape[2]
    G = H // KV
    kg = jnp.moveaxis(cache.k_view(), 3, 1)        # (B, KV, P, page, hd)
    vg = jnp.moveaxis(cache.v_view(), 3, 1)
    return ref.paged_attention_ref(q.reshape(B, KV, G, hd), kg, vg,
                                   cache.pos_view(), cur).reshape(B, H, hd)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("page", [8, 16])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_block_table_kernel_matches_dense_ref(policy, page, dtype):
    cache, steps = _driven_cache(policy, page, dtype)
    B, KV, hd, G = 2, 2, 64, 2
    q = jax.random.normal(jax.random.PRNGKey(99), (B, KV * G, hd))
    cur = jnp.full((B,), steps - 1, jnp.int32)
    out = np.asarray(ops.paged_attention(q, cache, cur_pos=cur), np.float32)
    exp = np.asarray(_dense_reference(q, cache, cur), np.float32)
    tol = ATOL if dtype == "float32" else 5e-4   # int8: quantization noise
    np.testing.assert_allclose(out, exp, atol=tol, rtol=tol)


@pytest.mark.parametrize("policy", POLICIES)
def test_block_table_kernel_matches_model_oracle(policy):
    """ops.paged_attention == models.attention.paged_attention_ref on the
    same live pooled cache (integration of layouts)."""
    cache, steps = _driven_cache(policy, 8, "float32", seed=3)
    q = jax.random.normal(jax.random.PRNGKey(7), (2, 4, 64))
    cur = jnp.full((2,), steps - 1, jnp.int32)
    a = np.asarray(ops.paged_attention(q, cache, cur_pos=cur))
    b = np.asarray(model_ref(q, cache, cur_pos=cur))
    np.testing.assert_allclose(a, b, atol=ATOL)


def test_kernel_isolates_requests_sharing_the_pool():
    """Two requests' pages interleave arbitrarily in the physical pool after
    eviction churn; each request's attention must only see its own block
    table (no cross-request leakage through reallocated pages)."""
    cache, steps = _driven_cache("paged_eviction", 8, "float32", B=3, seed=5)
    q = jax.random.normal(jax.random.PRNGKey(11), (3, 4, 64))
    cur = jnp.full((3,), steps - 1, jnp.int32)
    batched = np.asarray(ops.paged_attention(q, cache, cur_pos=cur))
    for b in range(3):
        # request b alone, over the SAME pool, through only its block table
        solo = np.asarray(ops.paged_attention(q[b:b + 1], _restrict(cache, b),
                                              cur_pos=cur[b:b + 1]))
        np.testing.assert_allclose(batched[b:b + 1], solo, atol=ATOL)


def _restrict(cache, b):
    """View of one request over the SAME pool (row-sliced block table)."""
    return cache._replace(
        block_table=cache.block_table[b:b + 1],
        cur_page=cache.cur_page[b:b + 1],
        cur_off=cache.cur_off[b:b + 1],
    )


def test_window_masking_on_reallocated_pages():
    cache, steps = _driven_cache("streaming_llm", 8, "float32", seed=9)
    q = jax.random.normal(jax.random.PRNGKey(13), (2, 4, 64))
    cur = jnp.full((2,), steps - 1, jnp.int32)
    for w in (0, 8, 16):
        a = np.asarray(ops.paged_attention(q, cache, cur_pos=cur, window=w))
        b = np.asarray(model_ref(q, cache, cur_pos=cur, window=w))
        np.testing.assert_allclose(a, b, atol=ATOL)
