"""Trace schema v2 back-compat + writer crash safety (ISSUE 9 satellites).

- a checked-in **v1** trace fixture (PR 8's schema, pre-``rec``) stays
  valid under the version-dispatched validator, the CLI, and the
  ``roofline.py --obs`` summary path;
- v2 rejects what it must (bad version, bad rec) while the step record
  remains the v1 shape + discriminator;
- TraceWriter lands the buffered tail when the process dies on an
  unhandled exception (atexit fallback, exercised in a subprocess) and
  when the engine loop errors mid-run (flush-on-error).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.obs.trace import (TRACE_SCHEMA_V1, TRACE_STEP_SCHEMA,
                             validate_event, validate_file)
from repro.obs.trace import main as trace_main

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "trace_v1.jsonl")


# ---------------------------------------------------------------------------
# v1 back-compat on the checked-in fixture
# ---------------------------------------------------------------------------

def test_v1_fixture_validates():
    assert validate_file(FIXTURE) == []
    assert trace_main([FIXTURE]) == 0


def test_v1_fixture_summarizes_in_roofline(capsys):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import trace_summary
    with open(FIXTURE) as f:
        events = [json.loads(ln) for ln in f]
    rows = trace_summary(events)
    kinds = {r["kind"] for r in rows}
    assert kinds == {"prefill", "mixed", "decode"}   # idle dropped
    decode = next(r for r in rows if r["kind"] == "decode")
    assert decode["steps"] == 2
    assert decode["tokens_per_step"] == pytest.approx(2.5)
    # optional devstat fields may be absent on v1 records (obs off)
    assert decode["pages_churn_per_step"] == pytest.approx(1.0)


def test_v2_schema_is_v1_plus_discriminator():
    """The step record is structurally v1 + ``rec`` — nothing renamed or
    retyped, so v1 consumers keep working on v2 step records minus the one
    extra key."""
    assert set(TRACE_STEP_SCHEMA) - set(TRACE_SCHEMA_V1) == {"rec"}
    for key, spec in TRACE_SCHEMA_V1.items():
        assert TRACE_STEP_SCHEMA[key] == spec


def test_version_dispatch():
    with open(FIXTURE) as f:
        v1 = json.loads(f.readline())
    assert validate_event(v1) == []
    # an unversioned record (pre-PR-8 prototype files) validates as v1
    unversioned = dict(v1)
    del unversioned["v"]
    assert validate_event(unversioned) == [] or \
        validate_event(unversioned) == ["missing required field 'v'"]
    # v1 does not accept v2-only fields
    assert any("unknown" in e for e in validate_event(dict(v1, rec="step")))
    # v2 requires the discriminator, and rejects unknown versions
    v2 = dict(v1, v=2)
    assert any("bad rec" in e for e in validate_event(v2))
    assert validate_event(dict(v2, rec="step")) == []
    assert any("not in" in e for e in validate_event(dict(v1, v=3)))


def test_mixed_v1_v2_file_validates(tmp_path):
    """A file that grew across the version bump (v1 head, v2 tail) stays
    valid line-by-line."""
    with open(FIXTURE) as f:
        lines = f.read().splitlines()
    v2_step = json.dumps(dict(json.loads(lines[0]), v=2, rec="step"))
    v2_event = json.dumps({"v": 2, "rec": "event", "step": 9,
                           "etype": "evict", "page": 3, "slot": 0, "lpi": 1,
                           "score": 0.5})
    p = tmp_path / "mixed.jsonl"
    p.write_text("\n".join(lines + [v2_step, v2_event]) + "\n")
    assert validate_file(str(p)) == []


# ---------------------------------------------------------------------------
# crash safety
# ---------------------------------------------------------------------------

def test_writer_atexit_lands_tail_on_unhandled_exception(tmp_path):
    """Buffered records survive a crash: the writer's atexit fallback
    flushes the tail when the interpreter dies on an uncaught exception,
    with close() never called."""
    out = tmp_path / "crash.jsonl"
    prog = textwrap.dedent(f"""
        from repro.obs.trace import TraceWriter, TRACE_SCHEMA_VERSION
        w = TraceWriter({str(out)!r}, flush_every=10_000)   # never auto-flush
        for i in range(7):
            w.emit({{"v": TRACE_SCHEMA_VERSION, "rec": "step",
                     "step": i + 1, "kind": "decode", "t_ms": 1.0,
                     "plan_ms": 0.1, "step_ms": 0.9, "decode_rows": 1,
                     "prefill_rows": 0, "reset_rows": 0, "adopt_rows": 0,
                     "tokens": 1, "programs": 2, "finished": 0}})
        raise RuntimeError("mid-run crash")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True)
    assert r.returncode != 0 and "mid-run crash" in r.stderr
    assert validate_file(str(out)) == []
    tail = [json.loads(ln) for ln in out.read_text().splitlines()]
    assert [e["step"] for e in tail] == list(range(1, 8))


def test_writer_close_is_idempotent_and_unregisters(tmp_path):
    from repro.obs.trace import TraceWriter
    p = tmp_path / "t.jsonl"
    w = TraceWriter(str(p), flush_every=100)
    w.emit({"v": 2, "rec": "step"})
    w.close()
    w.close()                                    # no-op
    assert len(p.read_text().splitlines()) == 1
    with pytest.raises(ValueError):
        w.emit({})


def test_engine_run_flushes_trace_on_error(tmp_path, monkeypatch):
    """An exception inside the engine loop must not lose the buffered
    step records: run() flushes before propagating, so the trace ends at
    the failing step."""
    import jax
    from repro.configs import ASSIGNED_ARCHS, CacheConfig
    from repro.models import init_model
    from repro.obs import ObsConfig
    from repro.serving import Engine, SamplingParams

    trace = tmp_path / "t.jsonl"
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=2,
                 max_prompt_len=32, max_new_tokens=8,
                 sampling=SamplingParams(greedy=True), chunk_size=16,
                 obs=ObsConfig(trace_path=str(trace)))
    rng = np.random.default_rng(0)
    for _ in range(2):
        eng.submit(rng.integers(0, cfg.vocab_size, size=20)
                   .astype(np.int32))
    real_plan, calls = eng.scheduler.plan, [0]

    def dying_plan():
        calls[0] += 1
        if calls[0] > 3:
            raise RuntimeError("scheduler died")
        return real_plan()

    monkeypatch.setattr(eng.scheduler, "plan", dying_plan)
    with pytest.raises(RuntimeError, match="scheduler died"):
        eng.run()
    # default flush_every is 64 — without flush-on-error the file is empty
    assert validate_file(str(trace)) == []
    steps = [json.loads(ln) for ln in trace.read_text().splitlines()]
    assert len(steps) == 3
