"""int8 quantized paged cache (beyond-paper extension) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache
from repro.core.paged_cache import quantize_absmax, write_prompt_pages
from repro.kernels import ops
from repro.models import decode_step, forward_prefill, init_model, make_inputs
from repro.models.attention import paged_attention_ref

pytestmark = pytest.mark.slow  # heavy tier: full suite only


def test_quantize_roundtrip_error_bounded():
    for seed in range(3):
        x = jax.random.normal(jax.random.PRNGKey(seed), (2, 7, 3, 32)) * 3.0
        q, s = quantize_absmax(x)
        back = q.astype(jnp.float32) * (s / 127.0)[..., None]
        # absmax int8: error <= scale/127 per element
        bound = np.asarray(s)[..., None] / 127.0 * 0.5 + 1e-6
        assert (np.abs(np.asarray(back - x)) <= bound + 1e-5).all()


def test_quantized_cache_write_and_dequant():
    B, P, page, KV, hd = 2, 3, 4, 2, 16
    c = init_layer_cache(B, P, page, KV, hd, "int8")
    assert c.quantized and c.k.dtype == jnp.int8
    k = jax.random.normal(jax.random.PRNGKey(0), (B, 8, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (B, 8))
    c = write_prompt_pages(c, k, k, pos, jnp.ones((B, 8)))
    kd = c.k_dequant().reshape(B, P * page, KV, hd)[:, :8]
    rel = float(jnp.abs(kd - k).max() / jnp.abs(k).max())
    assert rel < 0.02


def test_quantized_attention_close_to_fp():
    B, P, page, KV, hd, G = 2, 4, 16, 2, 128, 4
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, 64, KV, hd))
    vv = jax.random.normal(jax.random.PRNGKey(2), (B, 64, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (B, 64))
    q = jax.random.normal(jax.random.PRNGKey(3), (B, KV * G, hd))
    cur = jnp.full((B,), 63, jnp.int32)
    ones = jnp.ones((B, 64))
    c8 = write_prompt_pages(init_layer_cache(B, P, page, KV, hd, "int8"),
                            kk, vv, pos, ones)
    cf = write_prompt_pages(init_layer_cache(B, P, page, KV, hd, "float32"),
                            kk, vv, pos, ones)
    o8 = np.asarray(paged_attention_ref(q, c8, cur_pos=cur))
    of = np.asarray(paged_attention_ref(q, cf, cur_pos=cur))
    assert np.abs(o8 - of).max() / np.abs(of).max() < 0.05


def test_int8_pallas_kernel_matches_ref():
    B, P, page, KV, hd, G = 2, 3, 16, 2, 128, 2
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, 48, KV, hd))
    vv = jax.random.normal(jax.random.PRNGKey(2), (B, 48, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(48, dtype=jnp.int32), (B, 48))
    q = jax.random.normal(jax.random.PRNGKey(3), (B, KV * G, hd))
    c8 = write_prompt_pages(init_layer_cache(B, P, page, KV, hd, "int8"),
                            kk, vv, pos, jnp.ones((B, 48)))
    for cur_val, w in ((47, 0), (30, 0), (47, 16)):
        cur = jnp.full((B,), cur_val, jnp.int32)
        a = np.asarray(ops.paged_attention(q, c8, cur_pos=cur, window=w))
        b = np.asarray(paged_attention_ref(q, c8, cur_pos=cur, window=w))
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("policy", ["paged_eviction", "full", "streaming_llm",
                                    "keydiff"])
def test_int8_end_to_end_decode(policy):
    """Whole model prefill+decode with a quantized cache stays finite and
    respects the budget for every policy (incl. keydiff's dequantized
    global rescoring)."""
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    pol = get_policy(policy)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy=policy,
                       dtype="int8")
    inp = make_inputs(jax.random.PRNGKey(1), cfg, 2, 48)
    lg, cache = forward_prefill(params, cfg, inp["tokens"], pol, ccfg,
                                total_seq_hint=64)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(10):
        lg, cache = decode_step(params, cfg, tok, cache, pol, ccfg)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    assert bool(jnp.isfinite(lg).all())
    kv = jax.tree.map(lambda a: a[0], cache.pattern[0].kv)
    if policy != "full":
        assert int(kv.total_valid().max()) <= 32 + 8


def test_int8_memory_is_half():
    c8 = init_layer_cache(2, 4, 16, 2, 128, "int8")
    cf = init_layer_cache(2, 4, 16, 2, 128, jnp.bfloat16)
    b8 = sum(a.size * a.dtype.itemsize for a in [c8.k, c8.v, c8.k_scale, c8.v_scale])
    bf = sum(a.size * a.dtype.itemsize for a in [cf.k, cf.v])
    assert b8 / bf < 0.54, (b8, bf)
