"""Serving engine + scheduler + sampler tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.models import init_model
from repro.serving import Engine, Request, SamplingParams, Scheduler, sample_tokens
from repro.serving.request import RequestStatus


@pytest.fixture(scope="module")
def small_engine():
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    return Engine(cfg, params, cache_cfg=ccfg, max_batch=3, max_prompt_len=48,
                  max_new_tokens=10), cfg


def test_engine_continuous_batching(small_engine):
    eng, cfg = small_engine
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, 48)).astype(np.int32))
            for _ in range(7)]
    done = eng.run()
    assert len(done) >= 7                      # module fixture may accumulate
    for r in reqs:
        assert r.finished
        assert r.num_generated == 10
        assert r.status == RequestStatus.FINISHED_LENGTH


def test_engine_greedy_determinism():
    cfg = ASSIGNED_ARCHS["stablelm-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="full",
                       dtype="float32")
    prompt = np.arange(20, dtype=np.int32) % cfg.vocab_size

    def gen():
        eng = Engine(cfg, params, cache_cfg=ccfg, max_batch=2,
                     max_prompt_len=32, max_new_tokens=8)
        r = eng.submit(prompt)
        eng.run()
        return r.output_tokens

    assert gen() == gen()


def test_engine_batch_isolation():
    """A request's output must not depend on what shares the batch."""
    cfg = ASSIGNED_ARCHS["stablelm-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    prompt = (np.arange(24, dtype=np.int32) * 7) % cfg.vocab_size

    eng1 = Engine(cfg, params, cache_cfg=ccfg, max_batch=2,
                  max_prompt_len=32, max_new_tokens=6)
    r_solo = eng1.submit(prompt)
    eng1.run()

    eng2 = Engine(cfg, params, cache_cfg=ccfg, max_batch=2,
                  max_prompt_len=32, max_new_tokens=6, seed=123)
    rng = np.random.default_rng(5)
    other = rng.integers(0, cfg.vocab_size, size=30).astype(np.int32)
    r_a = eng2.submit(other)
    r_b = eng2.submit(prompt)
    eng2.run()
    assert r_b.output_tokens == r_solo.output_tokens


def test_scheduler_fifo_and_slots():
    s = Scheduler(max_batch=2)
    reqs = [Request(i, np.zeros(4, np.int32)) for i in range(4)]
    for r in reqs:
        s.add(r)
    admitted = s.schedule()
    assert [r.request_id for _, r in admitted] == [0, 1]
    assert s.free_slots() == []
    reqs[0].status = RequestStatus.FINISHED_LENGTH
    s.retire(reqs[0])
    admitted2 = s.schedule()
    assert [r.request_id for _, r in admitted2] == [2]
    assert s.num_active == 2


def test_sampler_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 3)
    g = sample_tokens(key, logits, greedy=True)
    np.testing.assert_array_equal(np.asarray(g), [1, 1, 1])
    tk = sample_tokens(key, logits, temperature=1.0, top_k=1)
    np.testing.assert_array_equal(np.asarray(tk), [1, 1, 1])
    tp = sample_tokens(key, logits, temperature=1.0, top_p=0.5)
    np.testing.assert_array_equal(np.asarray(tp), [1, 1, 1])
    # full-temperature sampling stays within the vocab and varies
    samples = [int(sample_tokens(jax.random.PRNGKey(i),
                                 logits[:1], temperature=2.0)[0])
               for i in range(20)]
    assert set(samples) <= {0, 1, 2, 3}
    assert len(set(samples)) > 1


def test_engine_eviction_respects_budget(small_engine):
    eng, cfg = small_engine
    # long generation with tight budget: cache never exceeds budget + page
    ccfg = CacheConfig(page_size=8, cache_budget=16, policy="paged_eviction",
                       dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    e = Engine(cfg, params, cache_cfg=ccfg, max_batch=1, max_prompt_len=32,
               max_new_tokens=24)
    e.submit(np.arange(30, dtype=np.int32) % cfg.vocab_size)
    e.run()
    for rep in range(ASSIGNED_ARCHS["qwen2.5-3b"].reduced().num_layers):
        kv = jax.tree.map(lambda a: a[rep], e.cache.pattern[0].kv)
        assert int(kv.total_valid().max()) <= 16 + 8


def test_decode_step_pallas_path_matches_ref():
    """decode_step(use_pallas=True) — the Pallas paged-attention hot path —
    must produce the same logits as the pure-jnp reference path."""
    from repro.models import decode_step, forward_prefill, make_inputs
    from repro.models.transformer import init_model as _init
    from repro.core import get_policy

    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = _init(jax.random.PRNGKey(0), cfg)
    pol = get_policy("paged_eviction")
    ccfg = CacheConfig(page_size=16, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    inp = make_inputs(jax.random.PRNGKey(1), cfg, 2, 48)
    lg, cache = forward_prefill(params, cfg, inp["tokens"], pol, ccfg,
                                total_seq_hint=64)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg_ref, _ = decode_step(params, cfg, tok, cache, pol, ccfg,
                            use_pallas=False)
    lg_pal, _ = decode_step(params, cfg, tok, cache, pol, ccfg,
                            use_pallas=True)
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_pal),
                               atol=3e-4, rtol=3e-4)
