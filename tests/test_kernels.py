"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.block_score import block_score_kernel
from repro.kernels.flash_prefill import flash_attention_kernel
from repro.kernels.paged_attention import paged_attention_kernel

pytestmark = pytest.mark.slow  # heavy tier: full suite only

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16] if dtype == jnp.bfloat16 else TOLS[jnp.float32]


def _random_pool(key, B, KV, hd, P, page, dtype, unmapped=0):
    """Pool arrays + a scrambled block table (each request maps P distinct
    physical pages out of an oversized pool, optionally with unmapped
    holes)."""
    N = B * P + 3                      # spare free pages in the pool
    ks = jax.random.split(key, 4)
    kp = jax.random.normal(ks[0], (KV, N, page, hd), dtype)
    vp = jax.random.normal(ks[1], (KV, N, page, hd), dtype)
    pos = jax.random.randint(ks[2], (N, page), -1, P * page)
    perm = jax.random.permutation(ks[3], N)[:B * P].reshape(B, P)
    bt = perm.astype(jnp.int32)
    for i in range(unmapped):
        bt = bt.at[i % B, (7 * i) % P].set(-1)
    return kp, vp, pos, bt


@pytest.mark.parametrize("B,KV,G,hd,P,page", [
    (1, 1, 1, 64, 2, 8),
    (2, 2, 4, 128, 5, 16),
    (3, 4, 2, 128, 4, 16),
    (2, 8, 1, 64, 3, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, KV, G, hd, P, page, dtype):
    key = jax.random.PRNGKey(B * 100 + P)
    kq, kpool = jax.random.split(key)
    q = jax.random.normal(kq, (B, KV, G, hd), dtype)
    kp, vp, pos, bt = _random_pool(kpool, B, KV, hd, P, page, dtype,
                                   unmapped=2)
    cur = jnp.full((B,), P * page, jnp.int32)
    out = paged_attention_kernel(q, kp, vp, pos, bt, cur)
    exp = ref.paged_attention_block_table_ref(q, kp, vp, pos, bt, cur)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=_tol(dtype),
                               rtol=_tol(dtype))


def test_paged_attention_window_and_causality():
    key = jax.random.PRNGKey(7)
    B, KV, G, hd, P, page = 2, 2, 2, 64, 4, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    N = B * P
    kp = jax.random.normal(ks[1], (KV, N, page, hd))
    vp = jax.random.normal(ks[2], (KV, N, page, hd))
    # request b maps pages [b*P .. b*P+P), each holding positions 0..P*page
    bt = (jnp.arange(B, dtype=jnp.int32)[:, None] * P +
          jnp.arange(P, dtype=jnp.int32)[None, :])
    pos = jnp.tile(jnp.arange(P * page, dtype=jnp.int32).reshape(P, page),
                   (B, 1))
    cur = jnp.array([15, 20], jnp.int32)      # mask future positions
    for w in (0, 8):
        out = paged_attention_kernel(q, kp, vp, pos, bt, cur, window=w)
        exp = ref.paged_attention_block_table_ref(q, kp, vp, pos, bt, cur,
                                                  window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_paged_attention_ignores_unmapped_slots():
    """Unmapping a block-table slot must equal physically removing its page
    — even when the freed physical page still holds another request's
    plausible-looking positions (the stale-pool hazard)."""
    key = jax.random.PRNGKey(9)
    B, KV, G, hd, P, page = 1, 1, 2, 64, 4, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    N = P + 2
    kp = jax.random.normal(ks[1], (KV, N, page, hd))
    vp = jax.random.normal(ks[2], (KV, N, page, hd))
    pos = jnp.tile(jnp.arange(P * page, dtype=jnp.int32).reshape(P, page),
                   (1, 1)).reshape(P, page)
    pos = jnp.concatenate([pos, jnp.zeros((2, page), jnp.int32)], 0)  # stale
    cur = jnp.full((B,), P * page, jnp.int32)
    bt_full = jnp.arange(P, dtype=jnp.int32)[None, :]
    bt_holed = bt_full.at[0, 1].set(-1)
    out = paged_attention_kernel(q, kp, vp, pos, bt_holed, cur)
    exp = ref.paged_attention_block_table_ref(
        q, kp, vp, pos, jnp.asarray([[0, 2, 3]], jnp.int32), cur)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("N,page,KV,hd", [
    (2, 8, 1, 64),
    (8, 16, 2, 128),
    (6, 16, 8, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_score_sweep(N, page, KV, hd, dtype):
    key = jax.random.PRNGKey(N * 10 + KV)
    ks = jax.random.split(key, 3)
    kp = jax.random.normal(ks[0], (N, page, KV, hd), dtype)
    vp = jax.random.normal(ks[1], (N, page, KV, hd), dtype)
    pos = jax.random.randint(ks[2], (N, page), -1, 50)
    out = np.asarray(block_score_kernel(kp, vp, pos))
    exp = np.asarray(ref.block_score_ref(kp, vp, pos))
    fin = np.isfinite(exp)
    np.testing.assert_allclose(out[fin], exp[fin], rtol=_tol(dtype) * 4,
                               atol=_tol(dtype) * 4)
    np.testing.assert_array_equal(np.isinf(out), np.isinf(exp))


@pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
    (128, 2, 1, 64, 64, 64),
    (256, 4, 2, 128, 128, 128),
    (256, 4, 4, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(S, H, KV, hd, bq, bk, dtype):
    key = jax.random.PRNGKey(S + H)
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention_kernel(q, k, v, block_q=bq, block_k=bk)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_tol(dtype) * 2, rtol=_tol(dtype) * 2)


def test_flash_prefill_sliding_window():
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    B, S, H, KV, hd = 1, 256, 2, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = flash_attention_kernel(q, k, v, window=100, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, window=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_ops_wrapper_matches_model_ref():
    """kernels.ops.paged_attention == models.attention.paged_attention_ref
    on a live PagedLayerCache (integration of layouts)."""
    from repro.core import decode_append, get_policy, init_layer_cache
    from repro.configs import CacheConfig
    from repro.kernels import ops
    from repro.models.attention import paged_attention_ref as model_ref

    pol = get_policy("paged_eviction")
    ccfg = CacheConfig(page_size=8, cache_budget=16, policy="paged_eviction",
                       dtype="float32")
    cache = init_layer_cache(2, 3, 8, 2, 64, jnp.float32)
    rng = jax.random.PRNGKey(0)
    for t in range(20):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache, jax.random.normal(k1, (2, 2, 64)),
                            jax.random.normal(k2, (2, 2, 64)),
                            jnp.full((2,), t), pol, ccfg)
        cache = out.cache
    q = jax.random.normal(rng, (2, 4, 64))
    cur = jnp.full((2,), 19, jnp.int32)
    a = ops.paged_attention(q, cache, cur_pos=cur)
    b = model_ref(q, cache, cur_pos=cur)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_forward_train_pallas_flash_path_matches_ref():
    """forward_train(use_pallas=True): the flash-prefill kernel inside the
    full model must reproduce the blocked-jnp attention path."""
    import jax
    from repro.configs import ASSIGNED_ARCHS
    from repro.models import forward_train, init_model, make_inputs

    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()   # hd=64, S=128 tileable
    params = init_model(jax.random.PRNGKey(0), cfg)
    inp = make_inputs(jax.random.PRNGKey(1), cfg, 2, 128)
    ref_out, _ = forward_train(params, cfg, inp["tokens"], remat=False)
    pal_out, _ = forward_train(params, cfg, inp["tokens"], remat=False,
                               use_pallas=True)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(pal_out),
                               atol=3e-4, rtol=3e-4)
