"""MoE dispatch/combine unit tests (sort-based ranking + shard_map path)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.models.moe import (
    _rank_in_expert,
    init_moe,
    moe_capacity,
    moe_forward,
    moe_forward_decode,
)
from repro.sharding import rules

pytestmark = pytest.mark.slow  # heavy tier: full suite only


@pytest.fixture(scope="module")
def moe_cfg():
    return replace(ASSIGNED_ARCHS["mixtral-8x7b"].reduced(),
                   moe_capacity_factor=8.0)  # drop-free for oracle compare


def test_rank_in_expert_matches_naive():
    rng = np.random.default_rng(0)
    for _ in range(5):
        flat = rng.integers(0, 4, size=(3, 12)).astype(np.int32)
        rank = np.asarray(_rank_in_expert(jnp.asarray(flat), 4))
        for b in range(3):
            seen = {}
            for i, e in enumerate(flat[b]):
                expected = seen.get(e, 0)
                assert rank[b, i] == expected, (b, i, e)
                seen[e] = expected + 1


def test_moe_matches_dense_oracle(moe_cfg):
    p = init_moe(jax.random.PRNGKey(0), moe_cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, moe_cfg.d_model))
    out, stats = moe_forward(p, moe_cfg, x)
    oracle = jnp.stack([moe_forward_decode(p, moe_cfg, x[:, s])
                        for s in range(16)], axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=1e-4, rtol=1e-4)
    assert float(stats.dropped) == 0.0
    assert abs(float(jnp.sum(stats.load)) - 1.0) < 1e-5


def test_capacity_drops_overflow():
    cfg = replace(ASSIGNED_ARCHS["mixtral-8x7b"].reduced(),
                  moe_capacity_factor=0.3)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, stats = moe_forward(p, cfg, x)
    assert float(stats.dropped) > 0.0
    assert bool(jnp.isfinite(out).all())


def test_capacity_rounding():
    cfg = ASSIGNED_ARCHS["mixtral-8x7b"].reduced()
    cap = moe_capacity(cfg, 100)
    assert cap % 8 == 0 and cap >= 8


def test_shard_map_path_matches_plain(moe_cfg):
    """The manual (shard_map) region on a 1x1 mesh must equal the plain
    block bit-for-bit-ish (the f32 psum accumulator allows tiny drift)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p = init_moe(jax.random.PRNGKey(0), moe_cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, moe_cfg.d_model))
    plain, stats_plain = moe_forward(p, moe_cfg, x)
    with mesh:
        ac = rules.activation_constraint(mesh, 2)
        sm, stats_sm = jax.jit(
            lambda pp, xx: moe_forward(pp, moe_cfg, xx, ac=ac))(p, x)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(sm),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(stats_plain.load),
                               np.asarray(stats_sm.load), atol=1e-6)


def test_grads_flow_through_dispatch(moe_cfg):
    p = init_moe(jax.random.PRNGKey(0), moe_cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, moe_cfg.d_model))

    def loss(pp):
        out, stats = moe_forward(pp, moe_cfg, x)
        return jnp.sum(out ** 2) + 0.01 * stats.aux_loss

    g = jax.grad(loss)(p)
    norms = jax.tree.map(lambda a: float(jnp.abs(a).max()), g)
    assert max(jax.tree.leaves(norms)) > 0
    assert all(np.isfinite(v) for v in jax.tree.leaves(norms))


def test_expert_parallel_path_matches_plain(moe_cfg):
    """EP layout on a (1,1,1) mesh (identity a2a / psum) must equal the
    plain block — validates the dispatch/exchange/combine plumbing."""
    mesh = jax.make_mesh((1, 1, 1), ("data", "expert", "tp"))
    p = init_moe(jax.random.PRNGKey(0), moe_cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, moe_cfg.d_model))
    plain, _ = moe_forward(p, moe_cfg, x)
    with mesh:
        ac = rules.activation_constraint(mesh, 2)
        assert getattr(ac, "mesh", None) is not None
        ep_out, stats = jax.jit(
            lambda pp, xx: moe_forward(pp, moe_cfg, xx, ac=ac))(p, x)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(ep_out),
                               atol=2e-5, rtol=2e-5)
    assert float(stats.dropped) == 0.0
