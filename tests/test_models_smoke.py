"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates its REDUCED variant (<=4 layers, d_model
<=256, <=4 experts — same family/pattern) and runs one forward/train step
plus a prefill+decode round trip on CPU, asserting output shapes and no
NaNs. The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, CacheConfig
from repro.core import get_policy
from repro.models import (
    decode_step,
    forward_prefill,
    forward_train,
    init_model,
    make_inputs,
)
from repro.training import AdamWConfig, init_adamw, make_train_step, lm_batch, DataConfig

pytestmark = pytest.mark.slow  # heavy tier: full suite only

ARCH_IDS = sorted(ASSIGNED_ARCHS)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = ASSIGNED_ARCHS[arch].reduced()
    cfg.validate()
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    inp = make_inputs(jax.random.PRNGKey(1), cfg, B, S)
    logits, aux = forward_train(params, cfg, inp["tokens"], cond=inp["cond"])
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = ASSIGNED_ARCHS[arch].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    step = make_train_step(cfg, AdamWConfig(total_steps=10, warmup_steps=1))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=2)
    batch = {k: jnp.asarray(v) for k, v in
             lm_batch(dcfg, 0, num_codebooks=cfg.num_codebooks).items()}
    cond = make_inputs(jax.random.PRNGKey(1), cfg, 2, 64)["cond"]
    params2, opt2, metrics = jax.jit(
        lambda p, o, b: step(p, o, b, cond=cond))(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss NaN"
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = ASSIGNED_ARCHS[arch].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    pol = get_policy("paged_eviction")
    ccfg = CacheConfig(page_size=8, cache_budget=32, policy="paged_eviction",
                       dtype="float32")
    B, S = 2, 48
    inp = make_inputs(jax.random.PRNGKey(1), cfg, B, S)
    logits, cache = forward_prefill(params, cfg, inp["tokens"], pol, ccfg,
                                    cond=inp["cond"], total_seq_hint=S + 8)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        logits, cache = decode_step(params, cfg, tok, cache, pol, ccfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN"
    assert int(cache.cur_pos[0]) == S + 4


@pytest.mark.parametrize("arch", sorted(PAPER_ARCHS))
def test_smoke_paper_archs(arch):
    """The paper's own Llama trio (reduced) also runs end to end."""
    cfg = PAPER_ARCHS[arch].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    inp = make_inputs(jax.random.PRNGKey(1), cfg, 2, 32)
    logits, _ = forward_train(params, cfg, inp["tokens"])
    assert bool(jnp.isfinite(logits).all())
