"""Policy behaviour tests — the paper's algorithms as executable claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache, POLICIES
from repro.core.prefill import compress_and_page


def _ccfg(policy, page=4, budget=16, **kw):
    return CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype="float32", **kw)


def _run_decode(policy_name, steps=40, B=2, KV=2, hd=8, budget=16, page=4,
                key=0):
    pol = get_policy(policy_name)
    cfg = _ccfg(policy_name, page=page, budget=budget)
    pages = pol.slab_pages(cfg, steps)
    cache = init_layer_cache(B, pages, page, KV, hd, jnp.float32)
    rng = jax.random.PRNGKey(key)
    outcomes = []
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        k = jax.random.normal(k1, (B, KV, hd))
        v = jax.random.normal(k2, (B, KV, hd))
        out = decode_append(cache, k, v, jnp.full((B,), t), pol, cfg)
        cache = out.cache
        outcomes.append(out)
    return cache, outcomes, cfg


# ---------------------------------------------------------------------------
# PagedEviction (the paper)
# ---------------------------------------------------------------------------

def test_paged_eviction_budget_bound():
    cache, _, cfg = _run_decode("paged_eviction", steps=60)
    # budget C plus at most one working page may be live transiently
    assert int(cache.total_valid().max()) <= cfg.cache_budget + cfg.page_size


def test_paged_eviction_structured_occupancy():
    """Paper Limitation 1: after any step, every non-working page is either
    FULL or EMPTY — the structural invariant unstructured baselines break."""
    cache, _, cfg = _run_decode("paged_eviction", steps=57)
    tpp = np.asarray(cache.tokens_per_page())           # (B, P)
    cur = np.asarray(cache.cur_page)
    for b in range(tpp.shape[0]):
        for p in range(tpp.shape[1]):
            if p == cur[b]:
                continue
            assert tpp[b, p] in (0, cfg.page_size), (b, p, tpp[b, p])


def test_paged_eviction_frequency_is_block_interval():
    """Paper Limitation 4: evictions happen only when a page fills — once
    every `page_size` steps at steady state, never more often."""
    _, outcomes, cfg = _run_decode("paged_eviction", steps=64)
    ev = [bool(o.pages_evicted.any()) for o in outcomes]
    ev_steps = [i for i, e in enumerate(ev) if e]
    assert all(b - a >= cfg.page_size for a, b in zip(ev_steps, ev_steps[1:]))
    assert len(ev_steps) >= 5  # it does evict at steady state


def test_paged_eviction_evicts_lowest_scoring_page():
    pol = get_policy("paged_eviction")
    cfg = _ccfg("paged_eviction", page=4, budget=8)
    cache = init_layer_cache(1, 3, 4, 1, 4, jnp.float32)
    # page0: low ||v||/||k|| ; page1: high; then trigger eviction via page2
    for t in range(4):
        out = decode_append(cache, jnp.ones((1, 1, 4)), 0.1 * jnp.ones((1, 1, 4)),
                            jnp.array([t]), pol, cfg)
        cache = out.cache
    for t in range(4, 8):
        out = decode_append(cache, jnp.ones((1, 1, 4)), 10.0 * jnp.ones((1, 1, 4)),
                            jnp.array([t]), pol, cfg)
        cache = out.cache
    for t in range(8, 12):
        out = decode_append(cache, jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)),
                            jnp.array([t]), pol, cfg)
        cache = out.cache
    # after the 12th write the budget (8) is exceeded -> page0 (score 0.1)
    # must be the victim: its positions 0..3 are gone
    live = set(np.asarray(cache.pos_view()).ravel().tolist()) - {-1}
    assert live.isdisjoint({0, 1, 2, 3})
    assert {4, 5, 6, 7}.issubset(live)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def test_full_cache_never_evicts():
    cache, outcomes, _ = _run_decode("full", steps=40)
    assert int(cache.total_valid().min()) == 40
    assert not any(bool(o.pages_evicted.any() or o.tokens_evicted.any())
                   for o in outcomes)


def test_streaming_llm_keeps_sinks_and_recent():
    cache, _, cfg = _run_decode("streaming_llm", steps=50, budget=16)
    pos = np.asarray(cache.pos_view())
    for b in range(pos.shape[0]):
        live = set(pos[b].ravel().tolist()) - {-1}
        for s in range(cfg.num_sink_tokens):
            assert s in live, f"sink {s} evicted"
        for r in range(50 - 8, 50):
            assert r in live, f"recent {r} evicted"
        assert len(live) <= cfg.cache_budget


def test_streaming_llm_evicts_every_step_once_full():
    _, outcomes, cfg = _run_decode("streaming_llm", steps=40, budget=16)
    ev = [bool(o.tokens_evicted.any()) for o in outcomes]
    # paper: one token per step once the budget is hit (overhead claim)
    assert all(ev[17:])
    assert not any(ev[:16])


def test_unstructured_evicts_lowest_score_token():
    pol = get_policy("inverse_key_l2")
    cfg = _ccfg("inverse_key_l2", page=4, budget=8)
    cache = init_layer_cache(1, 6, 4, 1, 4, jnp.float32)
    norms = [1.0] * 8 + [5.0]           # 9th token has a huge key norm
    for t, s in enumerate(norms):
        out = decode_append(cache, s * jnp.ones((1, 1, 4)), jnp.ones((1, 1, 4)),
                            jnp.array([t]), pol, cfg)
        cache = out.cache
    live = set(np.asarray(cache.pos_view()).ravel().tolist()) - {-1}
    assert 8 not in live                 # evicted immediately (highest ||k||)


def test_unstructured_fragmentation_vs_paged():
    """Paper Fig. 6: token-level eviction leaves partially-filled pages;
    PagedEviction does not."""
    frag_cache, _, cfg = _run_decode("inverse_key_l2", steps=60, budget=16)
    tpp = np.asarray(frag_cache.tokens_per_page())
    cur = np.asarray(frag_cache.cur_page)
    partial = sum(1 for b in range(tpp.shape[0]) for p in range(tpp.shape[1])
                  if p != cur[b] and 0 < tpp[b, p] < cfg.page_size)
    assert partial > 0, "unstructured policy should fragment pages"


def test_keydiff_prefers_diverse_keys():
    pol = get_policy("keydiff")
    cfg = _ccfg("keydiff", page=4, budget=8)
    cache = init_layer_cache(1, 6, 4, 1, 4, jnp.float32)
    base = jnp.asarray([[[1.0, 0.0, 0.0, 0.0]]])
    for t in range(8):
        out = decode_append(cache, base, jnp.ones((1, 1, 4)),
                            jnp.array([t]), pol, cfg)
        cache = out.cache
    ortho = jnp.asarray([[[0.0, 1.0, 0.0, 0.0]]])
    out = decode_append(cache, ortho, jnp.ones((1, 1, 4)),
                        jnp.array([8]), pol, cfg)
    cache = out.cache
    live = set(np.asarray(cache.pos_view()).ravel().tolist()) - {-1}
    assert 8 in live, "the diverse key must survive"


# ---------------------------------------------------------------------------
# prefill (Alg. 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_prefill_compress_budget_and_order(policy):
    key = jax.random.PRNGKey(3)
    B, S, KV, hd = 2, 40, 2, 8
    k = jax.random.normal(key, (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = jnp.ones((B, S), bool)
    pol = get_policy(policy)
    cfg = _ccfg(policy, page=8, budget=16)
    cache = compress_and_page(k, v, positions, valid, pol, cfg)
    tv = int(cache.total_valid()[0])
    if policy == "full":
        assert tv == S
    else:
        assert tv == cfg.cache_budget
    # retained tokens stay in position order within the slab
    pos = np.asarray(cache.pos_view()[0]).ravel()
    live = pos[pos >= 0]
    assert (np.diff(live) > 0).all()


def test_prefill_paged_eviction_keeps_top_scores():
    key = jax.random.PRNGKey(4)
    B, S, KV, hd = 1, 32, 1, 8
    k = jnp.ones((B, S, KV, hd))
    scales = jnp.linspace(0.1, 3.2, S)               # increasing ||v||
    v = jnp.ones((B, S, KV, hd)) * scales[None, :, None, None]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    pol = get_policy("paged_eviction")
    cfg = _ccfg("paged_eviction", page=8, budget=16)
    cache = compress_and_page(k, v, positions, jnp.ones((B, S), bool), pol, cfg)
    live = sorted(np.asarray(cache.pos_view()[0]).ravel().tolist())
    live = [p for p in live if p >= 0]
    assert live == list(range(16, 32)), "top-16 by ||v||/||k|| = last 16"


def test_prefill_handles_padding():
    key = jax.random.PRNGKey(5)
    B, S = 2, 24
    k = jax.random.normal(key, (B, S, 1, 8))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 1, 8))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = positions < jnp.asarray([[10], [24]])
    pol = get_policy("paged_eviction")
    cfg = _ccfg("paged_eviction", page=8, budget=16)
    cache = compress_and_page(k, v, jnp.where(valid, positions, -1), valid,
                              pol, cfg)
    assert int(cache.total_valid()[0]) == 10
    assert int(cache.total_valid()[1]) == 16
