"""Telemetry subsystem (repro.obs + core.devstats; DESIGN.md §9).

- histogram bucket math: interpolated p50/p90/p99 vs numpy percentiles
  within one log-bucket width; exact count/sum/min/max
- counter monotonicity, gauge last-write-wins, snapshot JSON round-trip
- trace JSONL: schema round-trip through a TraceWriter, validator catches
  malformed events, CLI entry point
- device stats vector vs HOST-recomputed pool accounting: exact per-step
  match of the conservation identities across a churned mixed workload
  (prefix-sharing adoptions, CoW forks, page evictions, force-evicts) for
  both structured and unstructured policies
- zero host callbacks inside the jitted step; with obs disabled the cache
  pytree is byte-identical in structure to the pre-obs engine (stats
  leaves are None, which vanish from the pytree)
- TTFT accounting under prefix sharing (ISSUE 8 satellite): adopters'
  TTFT stays ARRIVAL-based — deferral/queueing time cannot be hidden by
  the shorter prefill — and admission/first-token stamps are ordered
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CacheConfig
from repro.core import devstats
from repro.core import paged_cache as pc
from repro.models import init_model
from repro.obs import (MetricsRegistry, ObsConfig, TraceWriter,
                       validate_event, validate_file)
from repro.obs.metrics import Histogram
from repro.obs.trace import TRACE_SCHEMA_VERSION
from repro.serving import Engine, SamplingParams


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    # latency-shaped draws spanning several buckets
    xs = np.exp(rng.normal(np.log(5e-3), 1.0, size=5000))
    h = Histogram("t")
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.min == xs.min() and h.max == xs.max()
    assert h.sum == pytest.approx(xs.sum())
    width = 10 ** (1 / 8)      # LATENCY_BOUNDS_S: 8 buckets per decade
    for q in (0.5, 0.9, 0.99):
        est, ref = h.quantile(q), float(np.percentile(xs, q * 100))
        assert ref / width <= est <= ref * width, (q, est, ref)
    assert h.quantile(0.0) == xs.min()
    assert h.quantile(1.0) == xs.max()


def test_histogram_empty_and_overflow():
    h = Histogram("t")
    assert math.isnan(h.quantile(0.5))
    assert h.snapshot()["p50"] is None
    h.observe(1e9)             # beyond the last bound -> overflow bucket
    assert h.snapshot()["overflow"] == 1
    assert h.quantile(0.5) == 1e9     # exact max clamps the overflow bucket


def test_counter_monotone_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(5)
    assert c.value == 6
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 6
    g = reg.gauge("g")
    g.set(3)
    g.set(1)
    assert g.value == 1
    with pytest.raises(TypeError):
        reg.gauge("c")         # name already holds a counter


def test_registry_snapshot_json_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a.b").inc(2)
    reg.gauge("a.g").set(7)
    reg.histogram("a.h").observe(0.01)
    p = tmp_path / "snap.json"
    reg.to_json(str(p))
    snap = json.loads(p.read_text())
    assert snap["a.b"] == {"type": "counter", "value": 2}
    assert snap["a.g"]["value"] == 7
    assert snap["a.h"]["count"] == 1 and snap["a.h"]["p50"] is not None
    assert reg.render()        # dashboard renders without raising


# ---------------------------------------------------------------------------
# trace writer + schema
# ---------------------------------------------------------------------------

def _event(step=1, **kw):
    ev = {"v": TRACE_SCHEMA_VERSION, "rec": "step", "step": step,
          "kind": "decode",
          "t_ms": 1.0, "plan_ms": 0.1, "step_ms": 0.9, "decode_rows": 2,
          "prefill_rows": 0, "reset_rows": 0, "adopt_rows": 0, "tokens": 2,
          "programs": 2, "finished": 0}
    ev.update(kw)
    return ev


def test_trace_roundtrip_and_validation(tmp_path):
    p = tmp_path / "t.jsonl"
    with TraceWriter(str(p), flush_every=4) as w:
        for i in range(10):
            w.emit(_event(step=i + 1, pages_allocated=i))
    assert validate_file(str(p)) == []
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert len(lines) == 10
    assert [e["step"] for e in lines] == list(range(1, 11))
    assert lines[3]["pages_allocated"] == 3


def test_trace_validator_catches_bad_events(tmp_path):
    assert validate_event(_event()) == []
    assert any("missing" in e for e in validate_event({"v": 1}))
    assert any("kind" in e for e in validate_event(_event(kind="bogus")))
    assert any("unknown" in e for e in validate_event(_event(zzz=1)))
    assert any("expected int" in e for e in validate_event(_event(tokens=1.5)))
    p = tmp_path / "bad.jsonl"
    p.write_text('{"v": 1}\nnot json\n')
    errs = validate_file(str(p))
    assert errs and any("not JSON" in e for e in errs)
    from repro.obs.trace import main as trace_main
    assert trace_main([str(p)]) == 1
    good = tmp_path / "good.jsonl"
    with TraceWriter(str(good)) as w:
        w.emit(_event())
    assert trace_main([str(good)]) == 0


def test_trace_writer_buffers(tmp_path):
    p = tmp_path / "b.jsonl"
    w = TraceWriter(str(p), flush_every=100)
    w.emit(_event())
    assert p.read_text() == ""          # buffered, not yet written
    w.close()
    assert len(p.read_text().splitlines()) == 1
    with pytest.raises(ValueError):
        w.emit(_event())                # closed


# ---------------------------------------------------------------------------
# device stats vector — unit identities on raw pool ops
# ---------------------------------------------------------------------------

def test_devstats_bump_disabled_is_none():
    assert devstats.bump(None, devstats.PAGES_ALLOCATED, jnp.ones(3)) is None


def test_devstats_identities_raw_ops():
    cache = pc.init_layer_cache(4, 6, 4, 2, 8, jnp.float32, track_stats=True)
    ref0, free0 = int(cache.ref_count.sum()), int(cache.num_free())
    for t in range(10):
        k = jnp.ones((4, 2, 8))
        cache = pc.chunk_rollover(cache, cache.cur_off >= cache.page_size)
        cache = pc.write_token(cache, k, k, jnp.full((4,), t, jnp.int32),
                               jnp.ones((4,)))
    cache = pc.release_rows(cache, jnp.array([False, False, False, True]))
    cache = pc.adopt_prefix(cache, jnp.array([-1, -1, -1, 0]),
                            jnp.array([0, 0, 0, 2]))
    cache = pc.evict_token(cache, jnp.array([0, 0, 0, 1]),
                           enable=jnp.array([False, False, False, True]))
    cache = pc.evict_page(cache, jnp.array([1, 1, 1, 1]),
                          enable=jnp.array([True, False, False, False]))
    d = devstats.to_dict(np.asarray(cache.stats))
    ref1, free1 = int(cache.ref_count.sum()), int(cache.num_free())
    mapped = int((np.asarray(cache.block_table) >= 0).sum())
    assert ref1 - ref0 == (d["pages_allocated"] + d["pages_adopted"]
                           - d["pages_released"])
    assert free1 - free0 == d["pages_freed"] - d["pages_allocated"]
    assert mapped == ref1                       # F2: one ref per bt entry
    assert d["pages_forked"] == 1               # the CoW fork under evict
    assert d["tokens_evicted"] == 1
    assert d["tokens_written"] == 40


# ---------------------------------------------------------------------------
# engine-level: device stats vs host-recomputed pool accounting
# ---------------------------------------------------------------------------

def _make_engine(policy, *, max_batch=3, budget=32, page=8, chunk=16,
                 new_tokens=6, prompt_max=48, obs=None, sharing=True):
    cfg = ASSIGNED_ARCHS["qwen2.5-3b"].reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = CacheConfig(page_size=page, cache_budget=budget, policy=policy,
                       dtype="float32")
    return cfg, Engine(cfg, params, cache_cfg=ccfg, max_batch=max_batch,
                       max_prompt_len=prompt_max, max_new_tokens=new_tokens,
                       sampling=SamplingParams(greedy=True), chunk_size=chunk,
                       prefix_sharing=sharing, obs=obs)


def _host_pool_state(eng):
    """(ref_sum, free, mapped) summed over every attention layer (incl.
    stacked pattern reps) — recomputed from device arrays, independent of
    the stats vector."""
    ref_sum = free = mapped = 0
    for lc in list(eng.cache.pattern) + list(eng.cache.tail):
        if lc.kv is None:
            continue
        ref = np.asarray(jax.device_get(lc.kv.ref_count))
        bt = np.asarray(jax.device_get(lc.kv.block_table))
        ref_sum += int(ref.sum())
        free += int((ref == 0).sum())
        mapped += int((bt >= 0).sum())
    return ref_sum, free, mapped


def _pool_counters(eng):
    reg = eng.obs.registry
    return {name: reg.counter(f"pool.{name}").value
            for name in devstats.STAT_NAMES}


@pytest.mark.parametrize("policy", ["paged_eviction", "streaming_llm"])
def test_device_stats_match_host_pool_accounting(policy):
    """Across a churned mixed workload — shared-prefix admissions (adopt +
    CoW forks under token eviction), page evictions, retirements and
    re-admissions — the device stats vector reconciles EXACTLY with pool
    deltas recomputed on the host after every single step."""
    _, eng = _make_engine(policy)
    rng = np.random.default_rng(7)
    vocab = eng.cfg.vocab_size
    prefix = rng.integers(0, vocab, size=24)
    for i in range(6):
        tail = rng.integers(0, vocab, size=int(rng.integers(6, 20)))
        eng.submit(np.concatenate([prefix, tail]).astype(np.int32))
    steps = 0
    prev = _host_pool_state(eng)
    prev_ctr = _pool_counters(eng) if eng.stats.steps else \
        {n: 0 for n in devstats.STAT_NAMES}
    while eng.step() and steps < 200:
        steps += 1
        cur = _host_pool_state(eng)
        ctr = _pool_counters(eng)
        d = {n: ctr[n] - prev_ctr[n] for n in ctr}
        ref_d = cur[0] - prev[0]
        free_d = cur[1] - prev[1]
        assert ref_d == (d["pages_allocated"] + d["pages_adopted"]
                         - d["pages_released"]), (steps, d, prev, cur)
        assert free_d == d["pages_freed"] - d["pages_allocated"], \
            (steps, d, prev, cur)
        assert cur[2] == cur[0], (steps, cur)      # F2 over the fleet
        # the engine's running occupancy estimate never drifts
        assert eng._free_pages_est == cur[1], (steps, eng._free_pages_est, cur)
        prev, prev_ctr = cur, ctr
    assert len(eng.scheduler.finished) == 6
    final = _pool_counters(eng)
    assert final["pages_adopted"] > 0, "workload never exercised adoption"
    if policy == "paged_eviction":
        assert final["pages_evicted"] > 0, "workload never exercised eviction"
    else:   # token policy: evicts single tokens, CoW-forking shared pages
        assert final["tokens_evicted"] > 0
        assert final["pages_forked"] > 0, \
            "token eviction on shared pages must CoW-fork"
    assert eng._free_pages_est == eng.pool_stats()["free_pages"]


def test_forced_evictions_counted():
    """inverse_key_l2 under a starved pool scatters survivors one-per-page
    until rollover finds no free page — the force-evict path must land in
    the counter."""
    _, eng = _make_engine("inverse_key_l2", max_batch=4, budget=16, page=8,
                          chunk=8, new_tokens=20, prompt_max=32,
                          sharing=False)
    rng = np.random.default_rng(3)
    for _ in range(4):
        eng.submit(rng.integers(0, eng.cfg.vocab_size, size=30)
                   .astype(np.int32))
    eng.run(max_steps=300)
    assert eng._free_pages_est == eng.pool_stats()["free_pages"]
    assert eng.stats.tokens_evicted > 0


def test_engine_stats_eviction_fields_live():
    """EngineStats.pages_evicted/tokens_evicted/forced_evictions were dead
    fields before the obs PR — they must now track the device counters."""
    _, eng = _make_engine("paged_eviction")
    rng = np.random.default_rng(1)
    for _ in range(3):
        eng.submit(rng.integers(0, eng.cfg.vocab_size, size=40)
                   .astype(np.int32))
    eng.run()
    ctr = _pool_counters(eng)
    assert eng.stats.pages_evicted == ctr["pages_evicted"] > 0
    assert eng.stats.tokens_evicted == ctr["tokens_evicted"]
    assert eng.stats.forced_evictions == ctr["forced_evictions"]


# ---------------------------------------------------------------------------
# hot path stays clean: no callbacks, unchanged structure when disabled
# ---------------------------------------------------------------------------

def test_no_host_callbacks_inside_jit():
    _, eng = _make_engine("paged_eviction")
    B, T = eng.max_batch, 1
    args = (eng.params, jnp.zeros((B, T), jnp.int32),
            jnp.ones((B,), jnp.int32), jnp.ones((B,), bool),
            jnp.zeros((B,), bool), jnp.zeros((B,), bool),
            jnp.full((B,), -1, jnp.int32), jnp.zeros((B,), jnp.int32),
            eng.cache, jax.random.PRNGKey(0))
    jaxpr = str(jax.make_jaxpr(eng._step_impl)(*args))
    for prim in ("pure_callback", "io_callback", "python_callback",
                 "debug_callback"):
        assert prim not in jaxpr, f"host callback {prim} on the hot path"


def test_disabled_obs_restores_bare_pytree():
    """obs=ObsConfig(metrics=False): every stats leaf is None — the cache
    pytree structure (and therefore the compiled step) is identical to the
    pre-telemetry engine; the step output differs only by the trailing
    None stats slot."""
    _, off = _make_engine("paged_eviction",
                          obs=ObsConfig(metrics=False))
    _, on = _make_engine("paged_eviction")
    for lc in list(off.cache.pattern) + list(off.cache.tail):
        if lc.kv is not None:
            assert lc.kv.stats is None
    for lc in list(on.cache.pattern) + list(on.cache.tail):
        if lc.kv is not None:
            assert lc.kv.stats is not None
    # None leaves vanish from the pytree: the disabled cache's treedef has
    # strictly fewer leaves, and matches a cache built before this PR
    leaves_off = len(jax.tree_util.tree_leaves(off.cache))
    leaves_on = len(jax.tree_util.tree_leaves(on.cache))
    assert leaves_off < leaves_on
    rng = np.random.default_rng(0)
    p = rng.integers(0, off.cfg.vocab_size, size=20).astype(np.int32)
    for eng in (off, on):
        eng.submit(p.copy())
        eng.run()
    a = [r.output_tokens for r in off.scheduler.finished]
    b = [r.output_tokens for r in on.scheduler.finished]
    assert a == b, "telemetry changed sampled tokens"


# ---------------------------------------------------------------------------
# trace + snapshot from a real engine run
# ---------------------------------------------------------------------------

def test_engine_trace_and_snapshot(tmp_path):
    trace = tmp_path / "trace.jsonl"
    _, eng = _make_engine("paged_eviction",
                          obs=ObsConfig(trace_path=str(trace)))
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, eng.cfg.vocab_size, size=16)
    for _ in range(4):
        tail = rng.integers(0, eng.cfg.vocab_size, size=12)
        eng.submit(np.concatenate([prefix, tail]).astype(np.int32))
    eng.run()
    eng.close()
    assert validate_file(str(trace)) == []
    events = [json.loads(ln) for ln in trace.read_text().splitlines()]
    real = [e for e in events if e["kind"] != "idle"]
    assert len(real) == eng.stats.steps
    assert sum(e["finished"] for e in events) == 4
    assert sum(e["tokens"] for e in events) > 0
    # per-step device counters in the trace sum to the registry totals
    ctr = _pool_counters(eng)
    for name in devstats.STAT_NAMES:
        assert sum(e.get(name, 0) for e in events) == ctr[name], name
    assert all(e["free_pages"] >= 0 for e in real)
    snap = eng.metrics_snapshot()
    for h in ("engine.ttft_s", "engine.itl_s", "engine.tpot_s",
              "engine.step_wall_s", "engine.plan_s"):
        assert snap[h]["count"] > 0, h
        assert snap[h]["p50"] is not None and snap[h]["p99"] is not None, h
    assert snap["engine.programs"]["value"] == 2
    assert snap["engine.requests_finished"]["value"] == 4


# ---------------------------------------------------------------------------
# TTFT accounting under prefix sharing (satellite regression)
# ---------------------------------------------------------------------------

def test_ttft_dates_from_arrival_not_first_chunk():
    """Adopters skip their shared prefill chunks, and batched same-prefix
    arrivals are DEFERRED until the owner finishes prefilling the prefix.
    The TTFT interval must still start at arrival: an adopter's measured
    TTFT includes its queueing/deferral time, and the stamp ordering
    arrival <= admission < first_token holds for every request."""
    _, eng = _make_engine("paged_eviction", max_batch=4, budget=64,
                          prompt_max=64, chunk=8, new_tokens=4)
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, eng.cfg.vocab_size, size=32)
    reqs = []
    for _ in range(3):
        tail = rng.integers(0, eng.cfg.vocab_size, size=10)
        reqs.append(eng.submit(np.concatenate([prefix, tail])
                               .astype(np.int32)))
    eng.run()
    assert eng.stats.shared_prefix_hits >= 2   # followers adopted
    for r in reqs:
        assert r.arrival_time <= r.admission_time < r.first_token_time
        assert r.ttft == pytest.approx(r.first_token_time - r.arrival_time)
        assert r.ttft >= r.queue_time >= 0.0
    owner, followers = reqs[0], reqs[1:]
    for f in followers:
        assert f.shared_tokens > 0
        # the adopted pages cost no prefill compute ...
        assert f.prefill_time < owner.prefill_time
        # ... but deferral time is NOT hidden: the follower's first token
        # can only exist after the owner finished writing the prefix, so
        # its arrival-based TTFT is >= its own (shorter) prefill time
        assert f.ttft > f.prefill_time
    snap = eng.metrics_snapshot()
    assert snap["engine.queue_s"]["count"] == 3
    assert snap["engine.ttft_s"]["count"] == 3
