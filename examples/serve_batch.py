"""End-to-end serving driver: continuous batching with PagedEviction.

Submits a stream of variable-length requests to the engine, runs them to
completion with a tight cache budget, and reports throughput/TPOT — the
CPU-scale version of the paper's vLLM serving experiment (Fig. 3).

    PYTHONPATH=src python examples/serve_batch.py [--policy streaming_llm]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import CacheConfig, get_arch
from repro.models import init_model
from repro.serving import Engine, SamplingParams

ap = argparse.ArgumentParser()
ap.add_argument("--policy", default="paged_eviction")
ap.add_argument("--budget", type=int, default=64)
ap.add_argument("--requests", type=int, default=10)
args = ap.parse_args()

cfg = get_arch("llama-3.2-1b").reduced()
params = init_model(jax.random.PRNGKey(0), cfg)
ccfg = CacheConfig(page_size=8, cache_budget=args.budget, policy=args.policy,
                   dtype="float32")
engine = Engine(cfg, params, cache_cfg=ccfg, max_batch=4, max_prompt_len=96,
                max_new_tokens=32, sampling=SamplingParams(greedy=True))

rng = np.random.default_rng(0)
t0 = time.perf_counter()
reqs = []
for i in range(args.requests):
    n = int(rng.integers(16, 96))
    reqs.append(engine.submit(
        rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)))

finished = engine.run()
dt = time.perf_counter() - t0
s = engine.stats
print(f"policy={args.policy} budget={args.budget}")
print(f"{len(finished)} requests, {s.tokens_generated} tokens in {dt:.1f}s")
print(f"decode throughput: {s.decode_tok_per_s:.1f} tok/s, "
      f"TPOT {s.decode_s / max(s.steps, 1) * 1e3:.1f} ms "
      f"({s.steps} engine steps, continuous batching)")
for r in finished[:3]:
    print(f"  req {r.request_id}: prompt {len(r.prompt)} tok -> "
          f"{r.num_generated} generated, prefill {r.prefill_time * 1e3:.0f} ms")
