"""Quickstart: the paper's technique in ~40 lines.

Builds a reduced qwen2.5 model, prefills a prompt under a tight cache
budget with PagedEviction (Alg. 2), decodes a few tokens with block-wise
eviction (Alg. 3), and prints what happened to the cache.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CacheConfig, get_arch
from repro.core import get_policy
from repro.models import decode_step, forward_prefill, init_model, make_inputs

cfg = get_arch("qwen2.5-3b").reduced()
params = init_model(jax.random.PRNGKey(0), cfg)

# the paper's knobs: page size B and cache budget C
ccfg = CacheConfig(page_size=8, cache_budget=64, policy="paged_eviction",
                   dtype="float32")
policy = get_policy(ccfg.policy)

# a 96-token prompt: prefill compresses it to the 64-token budget BEFORE
# paging (token-level, Alg. 2)
prompt = make_inputs(jax.random.PRNGKey(1), cfg, batch=1, seq_len=96)["tokens"]
logits, cache = forward_prefill(params, cfg, prompt, policy, ccfg,
                                total_seq_hint=128)

# pattern-slot caches are stacked over layer repetitions: slice layer 0
layer0 = lambda c: jax.tree.map(lambda a: a[0], c.pattern[0].kv)
kv = layer0(cache)
print(f"prompt tokens : {prompt.shape[1]}")
print(f"cache budget  : {ccfg.cache_budget} tokens "
      f"({ccfg.budget_pages} pages of {ccfg.page_size})")
print(f"after prefill : {int(kv.total_valid()[0])} tokens live "
      f"(evicted {prompt.shape[1] - int(kv.total_valid()[0])})")

# decode: a whole page is evicted only when the newest page fills (Alg. 3)
tok = jnp.argmax(logits, -1).astype(jnp.int32)
for step in range(20):
    logits, cache = decode_step(params, cfg, tok, cache, policy, ccfg)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    kv = layer0(cache)
    tpp = np.asarray(kv.tokens_per_page())[0]
    if (step + 1) % 8 == 0:
        print(f"decode step {step + 1:2d}: live={int(kv.total_valid()[0]):3d} "
              f"pages={np.count_nonzero(tpp):2d} "
              f"occupancy={sorted(tpp[tpp > 0].tolist(), reverse=True)}")

print("note: every non-working page is exactly full — the paper's "
      "block-structure invariant.")
