"""Side-by-side cache traces for every eviction policy (paper Figs. 1/5/6).

Runs the same decode trace through all five policies and renders each
cache's page occupancy as ASCII — making the paper's structural argument
visible: PagedEviction keeps pages uniformly full; StreamingLLM slides;
unstructured policies fragment.

    PYTHONPATH=src python examples/eviction_comparison.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CacheConfig
from repro.core import decode_append, get_policy, init_layer_cache

PAGE, BUDGET, STEPS = 8, 32, 72
B, KV, HD = 1, 2, 16


def trace(policy_name):
    pol = get_policy(policy_name)
    cfg = CacheConfig(page_size=PAGE, cache_budget=BUDGET, policy=policy_name,
                      dtype="float32")
    cache = init_layer_cache(B, pol.slab_pages(cfg, STEPS), PAGE, KV, HD,
                             jnp.float32)
    rng = jax.random.PRNGKey(0)
    evictions = 0
    for t in range(STEPS):
        rng, k1, k2 = jax.random.split(rng, 3)
        out = decode_append(cache, jax.random.normal(k1, (B, KV, HD)),
                            jax.random.normal(k2, (B, KV, HD)),
                            jnp.full((B,), t), pol, cfg)
        cache = out.cache
        evictions += int(out.pages_evicted.any()) + int(out.tokens_evicted.any())
    return cache, evictions


def render(cache):
    """One char per slot: digit=page occupancy bucket, .=hole, |=page edge."""
    rows = []
    valid = np.asarray(cache.valid_mask())[0]
    for p in range(cache.num_pages):
        cells = "".join("#" if v else "." for v in valid[p])
        rows.append(cells)
    return " | ".join(rows)


print(f"page={PAGE} budget={BUDGET} decode_steps={STEPS}\n")
for pol in ["full", "paged_eviction", "streaming_llm", "inverse_key_l2",
            "keydiff"]:
    cache, ev = trace(pol)
    live = int(cache.total_valid()[0])
    tpp = np.asarray(cache.tokens_per_page())[0]
    frag = sum(1 for i, n in enumerate(tpp)
               if i != int(cache.cur_page[0]) and 0 < n < PAGE)
    print(f"{pol:16s} live={live:3d} eviction_ops={ev:3d} "
          f"fragmented_pages={frag}")
    print(f"  {render(cache)}\n")

print("PagedEviction: eviction ops ~ steps/page_size, zero fragmentation.")
print("Token-per-step baselines: eviction ops ~ steps, holes across pages.")
