"""End-to-end training driver: train a ~small model for a few hundred steps
on the synthetic LM stream, with checkpointing, then reload and serve one
prompt from the trained weights.

    PYTHONPATH=src python examples/train_small.py [--steps 200] [--arch qwen2.5-3b]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CacheConfig, get_arch
from repro.models import init_model
from repro.serving import Engine
from repro.training import (
    AdamWConfig,
    DataConfig,
    init_adamw,
    latest_step,
    lm_batch,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="qwen2.5-3b")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--size", default="reduced", choices=("reduced", "100m"),
                help="reduced = CPU smoke scale; 100m = ~100M-param run "
                     "(the deliverable's end-to-end driver; slower)")
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
if args.size == "100m":
    from dataclasses import replace
    cfg = replace(cfg, num_layers=12, d_model=704, num_heads=8,
                  num_kv_heads=8, head_dim=88, d_ff=2816, vocab_size=32000,
                  dtype="float32")
    n = cfg.param_count()
    print(f"100m config: {n/1e6:.0f}M params, {cfg.num_layers} layers")
params = init_model(jax.random.PRNGKey(0), cfg)
opt = init_adamw(params)
step = jax.jit(make_train_step(
    cfg, AdamWConfig(lr_peak=1e-3, warmup_steps=20, total_steps=args.steps)))
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                  batch_size=args.batch)

t0 = time.perf_counter()
first = last = None
with tempfile.TemporaryDirectory() as ckpt_dir:
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in
             lm_batch(dcfg, i, num_codebooks=cfg.num_codebooks).items()}
        params, opt, m = step(params, opt, b)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % max(args.steps // 8, 1) == 0:
            print(f"step {i:4d} loss {loss:.4f} lr {float(m['lr']):.2e}")
    print(f"trained {args.steps} steps in {time.perf_counter() - t0:.1f}s: "
          f"loss {first:.3f} -> {last:.3f}")

    save_checkpoint(ckpt_dir, args.steps, {"params": params})
    print(f"checkpoint saved at step {latest_step(ckpt_dir)}")
    restored = load_checkpoint(ckpt_dir, args.steps, {"params": params})

# serve from the trained weights with the paper's eviction policy
ccfg = CacheConfig(page_size=8, cache_budget=64, policy="paged_eviction",
                   dtype="float32")
eng = Engine(cfg, restored["params"], cache_cfg=ccfg, max_batch=2,
             max_prompt_len=64, max_new_tokens=16)
req = eng.submit(np.arange(48, dtype=np.int32) % cfg.vocab_size)
eng.run()
print(f"served from trained checkpoint: generated {req.output_tokens}")
