"""Long-context decode with a budget-capped cache — the long_500k story at
CPU scale.

Decodes far past the cache budget: the paged cache stays at a constant
~budget tokens while the *position* stream keeps growing (RoPE at true
positions, masks against true positions). This is exactly how the full
long_500k dry-run shape works: a dense model decodes at position 524288
with a 4096-token cache; here a reduced model decodes 600 tokens on a
64-token cache.

    PYTHONPATH=src python examples/long_context_decode.py [--policy ...]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CacheConfig, get_arch
from repro.core import get_policy
from repro.models import decode_step, forward_prefill, init_model, make_inputs

ap = argparse.ArgumentParser()
ap.add_argument("--policy", default="paged_eviction")
ap.add_argument("--budget", type=int, default=64)
ap.add_argument("--steps", type=int, default=600)
ap.add_argument("--arch", default="qwen2.5-3b")
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
params = init_model(jax.random.PRNGKey(0), cfg)
ccfg = CacheConfig(page_size=8, cache_budget=args.budget, policy=args.policy,
                   dtype="float32")
policy = get_policy(ccfg.policy)

prompt = make_inputs(jax.random.PRNGKey(1), cfg, 1, 96)["tokens"]
# total_seq_hint bounds the slab: with an eviction policy it is
# budget-capped regardless of how far we decode
logits, cache = forward_prefill(params, cfg, prompt, policy, ccfg,
                                total_seq_hint=96 + args.steps)
kv0 = jax.tree.map(lambda a: a[0], cache.pattern[0].kv)
slab_tokens = kv0.num_pages * kv0.page_size
print(f"slab: {kv0.num_pages} pages = {slab_tokens} token slots "
      f"(decoding {args.steps} tokens => context grows to "
      f"{96 + args.steps})")

step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, policy, ccfg))
tok = jnp.argmax(logits, -1).astype(jnp.int32)
t0 = time.perf_counter()
for i in range(args.steps):
    logits, cache = step(params, tok, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    if (i + 1) % 150 == 0:
        kv = jax.tree.map(lambda a: a[0], cache.pattern[0].kv)
        live = int(kv.total_valid()[0])
        pv = kv.pos_view()
        oldest = int(jnp.min(jnp.where(pv >= 0, pv, 10**9)))
        print(f"step {i + 1:4d}: position {int(cache.cur_pos[0]):4d}, "
              f"live tokens {live:3d} (budget {args.budget}), "
              f"oldest retained position {oldest}")
dt = time.perf_counter() - t0
assert bool(jnp.isfinite(logits).all())
print(f"decoded {args.steps} tokens in {dt:.1f}s "
      f"({args.steps / dt:.1f} tok/s) — cache stayed O(budget) while the "
      f"context grew {(96 + args.steps) / slab_tokens:.1f}x past the slab.")
